"""Pallas flash attention kernel vs the jnp oracle (interpret mode):
shape/dtype/GQA/causal sweep + agreement with the model's chunked path."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import flash_attention
from repro.kernels.flash.ref import flash_attention_ref


def _expand(k, g):
    return jnp.repeat(k, g, axis=1)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,hq,hkv,s,d,causal,tq,tk", [
    (2, 4, 2, 32, 16, True, 8, 8),
    (1, 2, 2, 64, 32, True, 16, 16),
    (1, 3, 1, 48, 8, False, 16, 16),
    (2, 2, 2, 40, 16, True, 16, 8),   # sq padded to tile
])
def test_flash_kernel_sweep(b, hq, hkv, s, d, causal, tq, tk, dtype):
    kk = jax.random.PRNGKey(b + s + d)
    q = jax.random.normal(kk, (b, hq, s, d), dtype=dtype)
    k = jax.random.normal(jax.random.fold_in(kk, 1), (b, hkv, s, d), dtype=dtype)
    v = jax.random.normal(jax.random.fold_in(kk, 2), (b, hkv, s, d), dtype=dtype)
    got = flash_attention(q, k, v, causal=causal, tq=tq, tk=tk)
    g = hq // hkv
    want = flash_attention_ref(
        q.reshape(b * hq, s, d).astype(jnp.float32),
        _expand(k, g).reshape(b * hq, s, d).astype(jnp.float32),
        _expand(v, g).reshape(b * hq, s, d).astype(jnp.float32), causal=causal)
    tol = dict(rtol=3e-2, atol=3e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(
        np.asarray(got.reshape(b * hq, s, d), np.float32),
        np.asarray(want, np.float32), **tol)


def test_flash_kernel_matches_model_attention():
    """Kernel == the model's chunked_attention (both flash formulations)."""
    from repro.models.attention import chunked_attention
    kk = jax.random.PRNGKey(7)
    b, hq, hkv, s, d = 1, 4, 2, 32, 16
    q = jax.random.normal(kk, (b, hq, s, d))
    k = jax.random.normal(jax.random.fold_in(kk, 1), (b, hkv, s, d))
    v = jax.random.normal(jax.random.fold_in(kk, 2), (b, hkv, s, d))
    got = flash_attention(q, k, v, causal=True, tq=8, tk=8)
    want = chunked_attention(q, k, v, causal=True, q_chunk=8, kv_chunk=8)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_flash_kernel_causal_block_skip_correct():
    """The skipped blocks must not change results: compare tile sizes that
    change the skip pattern."""
    kk = jax.random.PRNGKey(9)
    q = jax.random.normal(kk, (1, 2, 64, 8))
    k = jax.random.normal(jax.random.fold_in(kk, 1), (1, 2, 64, 8))
    v = jax.random.normal(jax.random.fold_in(kk, 2), (1, 2, 64, 8))
    a = flash_attention(q, k, v, causal=True, tq=8, tk=8)
    bb = flash_attention(q, k, v, causal=True, tq=32, tk=16)
    np.testing.assert_allclose(a, bb, rtol=2e-5, atol=2e-5)
