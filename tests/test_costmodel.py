"""Cost model (§IV): Table I constants, Fig 8 monotonicity, Fig 9 ratios
after calibration must reproduce the paper's headline numbers."""

import pytest

from repro.core import costmodel as cm


def test_table_i_values():
    """Table I is transcribed exactly from the paper."""
    assert cm.MEMORY_TABLE["ReRAM"] == (1.907, 1.623, 15.274, 13.948)
    assert cm.MEMORY_TABLE["eDRAM"] == (3.407, 3.324, 34.207, 66.661)
    assert cm.MEMORY_TABLE["SRAM"] == (6.687, 6.688, 144.556, 279.546)
    assert cm.MEMORY_TABLE["STT-RAM"] == (2.102, 1.975, 13.469, 18.06)


def test_table_i_orderings():
    """Paper's observations: ReRAM beats eDRAM/SRAM on all four metrics;
    vs STT-RAM it wins energy + read latency, loses write latency."""
    rr, ed, sr, st = (cm.MEMORY_TABLE[k] for k in ("ReRAM", "eDRAM", "SRAM", "STT-RAM"))
    for i in range(4):
        assert rr[i] < ed[i] < sr[i]
    assert rr[0] < st[0] and rr[1] < st[1] and rr[3] < st[3]
    assert rr[2] > st[2]  # write latency is ReRAM's known weakness


def test_fig8_monotone_and_normalized():
    rows = cm.normalized_fig8()
    assert rows[0]["layers"] == 2
    assert rows[0]["read_latency"] == pytest.approx(1.0)
    assert rows[0]["read_energy"] == pytest.approx(1.0)
    for a, b in zip(rows, rows[1:]):
        assert b["read_latency"] > a["read_latency"]
        assert b["read_energy"] > a["read_energy"]
        assert b["write_latency"] > a["write_latency"]


def test_flops_formula():
    l = cm.ConvLayer("x", n=2, c=3, h=4, w=5, l=3)
    assert l.flops == 2 * 2 * 3 * 9 * 4 * 5


def test_3d_faster_and_cheaper_than_2d_per_layer():
    for wl in cm.PAPER_WORKLOADS:
        r3, r2 = cm.cost_3d_reram(wl), cm.cost_2d_reram(wl)
        assert r3.time_s < r2.time_s, wl.name
        assert r3.energy_j < r2.energy_j, wl.name


def test_calibrated_model_reproduces_paper_fig9():
    """The four calibrated ratios must match the paper's numbers tightly;
    the two predicted energy ratios must land within 2x (cross-check --
    they share no dedicated knob)."""
    hw = cm.calibrate()
    r = cm.evaluate_fig9(hw=hw)
    p = cm.PAPER_FIG9
    assert r.speedup_vs_2d == pytest.approx(p.speedup_vs_2d, rel=0.02)
    assert r.speedup_vs_cpu == pytest.approx(p.speedup_vs_cpu, rel=0.02)
    assert r.speedup_vs_gpu == pytest.approx(p.speedup_vs_gpu, rel=0.02)
    assert r.energy_saving_vs_2d == pytest.approx(p.energy_saving_vs_2d, rel=0.05)
    assert p.energy_saving_vs_cpu / 3 < r.energy_saving_vs_cpu < p.energy_saving_vs_cpu * 3
    assert p.energy_saving_vs_gpu / 3 < r.energy_saving_vs_gpu < p.energy_saving_vs_gpu * 3


def test_calibrated_knobs_physically_plausible():
    hw = cm.calibrate()
    assert 1.0 < hw.fig8_lat_16 < 8.0          # Fig 8 shows a modest rise
    assert 0.5 <= hw.e_adc_pJ <= 60.0          # Murmann survey envelope
    assert 0.001 < hw.cpu_eta < 0.6            # measured TF efficiency range
    assert 0.001 < hw.gpu_eta < 0.6


def test_default_constants_close_to_calibrated():
    """DEFAULT_HW ships the calibrated values so users get paper-faithful
    numbers without re-running calibration."""
    r = cm.evaluate_fig9()
    p = cm.PAPER_FIG9
    assert r.speedup_vs_2d == pytest.approx(p.speedup_vs_2d, rel=0.10)
    assert r.speedup_vs_cpu == pytest.approx(p.speedup_vs_cpu, rel=0.10)
    assert r.speedup_vs_gpu == pytest.approx(p.speedup_vs_gpu, rel=0.10)
