"""Crossbar signal-chain simulator: quantization fidelity, scheme equivalence,
op-amp subtraction, high-precision convergence."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import crossbar as xbar


CFG_HI = xbar.CrossbarConfig(weight_bits=14, dac_bits=14, adc_bits=16, g_on_off_ratio=1e9)


def test_high_precision_converges_to_exact():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 32))
    w = jax.random.normal(jax.random.PRNGKey(1), (32, 16))
    got = xbar.crossbar_vmm(x, w, CFG_HI)
    np.testing.assert_allclose(got, x @ w, rtol=1e-2, atol=5e-3)


def test_ideal_scheme_is_exact():
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 8))
    w = jax.random.normal(jax.random.PRNGKey(3), (8, 8))
    cfg = xbar.CrossbarConfig(scheme="ideal")
    np.testing.assert_allclose(xbar.crossbar_vmm(x, w, cfg), x @ w, rtol=1e-6)


@pytest.mark.parametrize("bits", [4, 6, 8])
def test_error_decreases_with_bits(bits):
    x = jax.random.normal(jax.random.PRNGKey(4), (16, 64))
    w = jax.random.normal(jax.random.PRNGKey(5), (64, 32))
    exact = x @ w

    def err(b):
        cfg = xbar.CrossbarConfig(weight_bits=b, dac_bits=b, adc_bits=b + 2,
                                  g_on_off_ratio=1e9)
        out = xbar.crossbar_vmm(x, w, cfg)
        return float(jnp.linalg.norm(out - exact) / jnp.linalg.norm(exact))

    assert err(bits + 2) < err(bits) * 1.05  # monotone (small slack for ties)


def test_opamp_difference_identity():
    """Paper Fig. 7(e) proof: I2 = I_p - I_n."""
    ip = jnp.array([1.0, 2.0, 3.0])
    in_ = jnp.array([0.5, 2.5, 1.0])
    np.testing.assert_allclose(xbar.opamp_difference(ip, in_), ip - in_)


def test_conductances_nonnegative():
    w = jax.random.normal(jax.random.PRNGKey(6), (16, 16))
    g_pos, g_neg, scale = xbar.program_conductances(w, xbar.CrossbarConfig())
    assert float(g_pos.min()) >= 0.0 and float(g_neg.min()) >= 0.0
    assert float(scale) > 0.0


def test_tiled_matches_untiled_high_precision():
    x = jax.random.normal(jax.random.PRNGKey(7), (4, 300))
    w = jax.random.normal(jax.random.PRNGKey(8), (300, 200))
    got = xbar.crossbar_vmm_tiled(x, w, CFG_HI, tile_k=128, tile_m=128)
    np.testing.assert_allclose(got, x @ w, rtol=2e-2, atol=2e-2)


def test_read_noise_requires_key_and_perturbs():
    x = jnp.ones((2, 8))
    w = jnp.ones((8, 4))
    cfg = dataclasses.replace(CFG_HI, read_noise_sigma=0.05)
    with pytest.raises(ValueError):
        xbar.crossbar_vmm(x, w, cfg)
    out = xbar.crossbar_vmm(x, w, cfg, key=jax.random.PRNGKey(9))
    assert not np.allclose(out, x @ w, rtol=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    k=st.integers(1, 48), m=st.integers(1, 48), seed=st.integers(0, 2**31 - 1)
)
def test_property_bounded_relative_error(k, m, seed):
    """8-bit chain keeps relative error bounded for well-conditioned inputs."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(k1, (4, k))
    w = jax.random.normal(k2, (k, m))
    cfg = xbar.CrossbarConfig(weight_bits=8, dac_bits=8, adc_bits=12, g_on_off_ratio=1e9)
    out = xbar.crossbar_vmm(x, w, cfg)
    exact = x @ w
    denom = float(jnp.linalg.norm(exact)) + 1e-6
    rel = float(jnp.linalg.norm(out - exact)) / denom
    assert rel < 0.15, rel
