"""Pallas kernel tests: shape/dtype sweeps against the ref.py oracles,
interpret mode (kernel bodies execute on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import crossbar as xbar
from repro.kernels import conv1d_causal, crossbar_linear_pallas, crossbar_vmm, kn2row_conv
from repro.kernels.conv1d.ref import conv1d_causal_ref
from repro.kernels.crossbar_vmm.ref import crossbar_vmm_ref
from repro.kernels.kn2row.ref import kn2row_conv_ref


def _tol(dtype):
    # bf16 inputs: oracle runs in fp32; kernel output rounds to bf16 once.
    return dict(rtol=3e-2, atol=8e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-4, atol=2e-4)


# --------------------------------- kn2row ------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [
    # (b, c, h, w, n, l1, l2)
    (1, 4, 8, 16, 8, 3, 3),
    (2, 5, 16, 16, 7, 3, 3),
    (1, 3, 8, 16, 4, 5, 5),
    (1, 8, 8, 16, 16, 1, 1),
    (1, 2, 16, 32, 3, 1, 3),
])
def test_kn2row_kernel_sweep(shape, dtype):
    b, c, h, w, n, l1, l2 = shape
    k = jax.random.PRNGKey(hash(shape) % 2**31)
    img = jax.random.normal(k, (b, c, h, w), dtype=dtype)
    ker = jax.random.normal(jax.random.fold_in(k, 1), (n, c, l1, l2), dtype=dtype)
    got = kn2row_conv(img, ker, th=8, tw=16, ct=min(8, c))
    want = kn2row_conv_ref(img.astype(jnp.float32), ker.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_kn2row_kernel_tile_padding():
    """Non-divisible h/w/c exercise the pad-and-crop path."""
    k = jax.random.PRNGKey(0)
    img = jax.random.normal(k, (1, 5, 9, 13))
    ker = jax.random.normal(jax.random.fold_in(k, 1), (6, 5, 3, 3))
    got = kn2row_conv(img, ker, th=4, tw=8, ct=4)
    want = kn2row_conv_ref(img, ker)
    np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-4)


@settings(max_examples=8, deadline=None)
@given(c=st.integers(1, 6), n=st.integers(1, 8),
       l=st.sampled_from([1, 3, 5]), seed=st.integers(0, 2**31 - 1))
def test_kn2row_kernel_property(c, n, l, seed):
    k = jax.random.PRNGKey(seed)
    img = jax.random.normal(k, (1, c, 8, 16))
    ker = jax.random.normal(jax.random.fold_in(k, 1), (n, c, l, l))
    got = kn2row_conv(img, ker, th=8, tw=16, ct=min(4, c))
    want = kn2row_conv_ref(img, ker)
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=5e-4)


# --------------------------------- conv1d ------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,t,c,l", [
    (1, 16, 8, 4), (2, 32, 16, 4), (1, 8, 4, 1), (3, 24, 12, 7),
])
def test_conv1d_kernel_sweep(b, t, c, l, dtype):
    k = jax.random.PRNGKey(b * 1000 + t + c + l)
    x = jax.random.normal(k, (b, t, c), dtype=dtype)
    w = jax.random.normal(jax.random.fold_in(k, 1), (l, c), dtype=dtype)
    got = conv1d_causal(x, w, tt=8, ct=min(8, c))
    want = conv1d_causal_ref(x.astype(jnp.float32), w.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_conv1d_kernel_equals_model_path():
    """The kernel must agree with the conv used inside xLSTM/RG-LRU blocks."""
    from repro.core.kn2row import conv1d_depthwise_causal
    k = jax.random.PRNGKey(3)
    x = jax.random.normal(k, (2, 20, 10))
    w = jax.random.normal(jax.random.fold_in(k, 1), (4, 10))
    np.testing.assert_allclose(conv1d_causal(x, w, tt=4, ct=4),
                               conv1d_depthwise_causal(x, w),
                               rtol=2e-4, atol=2e-4)


# ------------------------------- crossbar_vmm --------------------------------


@pytest.mark.parametrize("m,k,n", [(8, 16, 8), (10, 24, 12), (128, 128, 128)])
@pytest.mark.parametrize("adc_bits", [6, 10])
def test_crossbar_kernel_sweep(m, k, n, adc_bits):
    kk = jax.random.PRNGKey(m + k + n)
    v = jax.random.normal(kk, (m, k))
    gp = jax.nn.relu(jax.random.normal(jax.random.fold_in(kk, 1), (k, n)))
    gn = jax.nn.relu(jax.random.normal(jax.random.fold_in(kk, 2), (k, n)))
    ir = jnp.asarray([float(k)])
    got = crossbar_vmm(v, gp, gn, ir, adc_bits=adc_bits, tm=8, tn=8, tk=8)
    want = crossbar_vmm_ref(v, gp, gn, ir, adc_bits=adc_bits)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_crossbar_kernel_signed_linear_end_to_end():
    """Signed-weight entry point vs the core simulator (same quantization
    config, separated scheme, per-column full-scale)."""
    kk = jax.random.PRNGKey(9)
    x = jax.random.normal(kk, (6, 32))
    w = jax.random.normal(jax.random.fold_in(kk, 1), (32, 16)) * 0.1
    cfg = xbar.CrossbarConfig(weight_bits=8, dac_bits=8, adc_bits=12,
                              g_on_off_ratio=1e9)
    got = crossbar_linear_pallas(x, w, cfg, tm=8, tn=8, tk=8)
    exact = x @ w
    rel = float(jnp.linalg.norm(got - exact) / jnp.linalg.norm(exact))
    assert rel < 0.05, rel


def test_crossbar_kernel_opamp_identity():
    """g_pos == g_neg must give exactly zero (op-amp difference)."""
    v = jnp.ones((8, 8))
    g = jnp.full((8, 8), 0.5)
    out = crossbar_vmm(v, g, g, jnp.asarray([8.0]), tm=8, tn=8, tk=8)
    np.testing.assert_array_equal(np.asarray(out), 0.0)
