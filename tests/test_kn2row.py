"""kn2row algorithm (paper §III.B): equivalence with direct conv + im2col."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import kn2row

jax.config.update("jax_enable_x64", False)


def _rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype=dtype)


@pytest.mark.parametrize("l", [1, 3, 5, 7])
@pytest.mark.parametrize("padding", ["SAME", "VALID"])
def test_kn2row_matches_direct(l, padding):
    img = _rand(0, (2, 5, 12, 11))
    ker = _rand(1, (7, 5, l, l))
    got = kn2row.conv2d_kn2row(img, ker, padding=padding)
    want = kn2row.conv2d_direct(img, ker, padding=padding)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("l1,l2", [(1, 3), (3, 1), (2, 2), (3, 5)])
def test_kn2row_rectangular(l1, l2):
    img = _rand(2, (1, 3, 9, 10))
    ker = _rand(3, (4, 3, l1, l2))
    got = kn2row.conv2d_kn2row(img, ker, padding="SAME")
    want = kn2row.conv2d_direct(img, ker, padding="SAME")
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("padding", ["SAME", "VALID"])
def test_im2col_matches_direct(padding):
    img = _rand(4, (2, 6, 10, 10))
    ker = _rand(5, (8, 6, 3, 3))
    got = kn2row.conv2d_im2col(img, ker, padding=padding)
    want = kn2row.conv2d_direct(img, ker, padding=padding)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@settings(max_examples=30, deadline=None)
@given(
    b=st.integers(1, 2),
    c=st.integers(1, 5),
    n=st.integers(1, 6),
    h=st.integers(3, 12),
    w=st.integers(3, 12),
    l=st.sampled_from([1, 2, 3, 5]),
    seed=st.integers(0, 2**31 - 1),
)
def test_kn2row_property(b, c, n, h, w, l, seed):
    """Property: kn2row == direct conv for any shape with l <= min(h, w)."""
    if l > min(h, w):
        l = 1
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    img = jax.random.normal(k1, (b, c, h, w))
    ker = jax.random.normal(k2, (n, c, l, l))
    got = kn2row.conv2d_kn2row(img, ker, padding="SAME")
    want = kn2row.conv2d_direct(img, ker, padding="SAME")
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=5e-4)


# ---------------- 1-D causal (xLSTM / RecurrentGemma path) ----------------


@pytest.mark.parametrize("l", [1, 2, 4, 7])
def test_conv1d_depthwise_causal(l):
    x = _rand(6, (3, 16, 8))
    w = _rand(7, (l, 8))
    got = kn2row.conv1d_depthwise_causal(x, w)
    want = kn2row.conv1d_depthwise_causal_ref(x, w)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_conv1d_causal_is_causal():
    """Changing x[t0] must not affect outputs before t0."""
    x = _rand(8, (1, 12, 4))
    w = _rand(9, (4, 4))
    y0 = kn2row.conv1d_depthwise_causal(x, w)
    x2 = x.at[:, 6, :].add(100.0)
    y1 = kn2row.conv1d_depthwise_causal(x2, w)
    np.testing.assert_allclose(y0[:, :6], y1[:, :6], rtol=1e-5, atol=1e-5)
    assert not np.allclose(y0[:, 6:], y1[:, 6:])


@pytest.mark.parametrize("l", [1, 3, 4])
def test_conv1d_dense_causal_matches_lax(l):
    x = _rand(10, (2, 10, 6))
    k = _rand(11, (l, 6, 9))
    got = kn2row.conv1d_causal_kn2row(x, k)
    # oracle: pad left, NCW conv
    xp = jnp.pad(x, ((0, 0), (l - 1, 0), (0, 0))).transpose(0, 2, 1)
    kr = k.transpose(2, 1, 0)  # (c_out, c_in, l)
    want = jax.lax.conv_general_dilated(
        xp, kr, (1,), "VALID", dimension_numbers=("NCH", "OIH", "NCH")
    ).transpose(0, 2, 1)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@settings(max_examples=20, deadline=None)
@given(
    t=st.integers(1, 20), c=st.integers(1, 8), l=st.integers(1, 6),
    seed=st.integers(0, 2**31 - 1),
)
def test_conv1d_property(t, c, l, seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(k1, (1, t, c))
    w = jax.random.normal(k2, (l, c))
    got = kn2row.conv1d_depthwise_causal(x, w)
    want = kn2row.conv1d_depthwise_causal_ref(x, w)
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=5e-4)
