"""PIM-mode linear layers: the paper's 'same inference accuracy' claim,
checked on LM-style projections through the simulated crossbar."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CrossbarConfig, CrossbarLinearConfig, Stack3DSpec
from repro.core import crossbar_linear, quantization_error


def _cfg(bits=8, adc=12):
    return CrossbarLinearConfig(
        xbar=CrossbarConfig(weight_bits=bits, dac_bits=bits, adc_bits=adc,
                            g_on_off_ratio=1e9),
        spec=Stack3DSpec(layers=16, wl_per_plane=128, bl_per_plane=128),
    )


def test_linear_matches_exact_high_precision():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 256))
    w = jax.random.normal(jax.random.PRNGKey(1), (256, 192)) / 16.0
    got = crossbar_linear(x, w, cfg=_cfg(bits=14, adc=18))
    np.testing.assert_allclose(got, x @ w, rtol=2e-2, atol=2e-2)


def test_bias_applied():
    x = jnp.ones((2, 8))
    w = jnp.eye(8)
    b = jnp.arange(8.0)
    out = crossbar_linear(x, w, b, cfg=_cfg(bits=14, adc=18))
    want = np.broadcast_to(1.0 + np.asarray(b)[None, :], out.shape)
    np.testing.assert_allclose(out, want, rtol=2e-2, atol=2e-2)


def test_accuracy_equivalence_8bit():
    """8-bit crossbar inference keeps relative error ~1% on Gaussian
    projections -- the quantitative form of the paper's accuracy claim."""
    x = jax.random.normal(jax.random.PRNGKey(2), (16, 512))
    w = jax.random.normal(jax.random.PRNGKey(3), (512, 384)) * 0.02
    err = float(quantization_error(x, w, _cfg(bits=8, adc=12)))
    assert err < 0.05, err


def test_dtype_preserved():
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 64), dtype=jnp.bfloat16)
    w = jax.random.normal(jax.random.PRNGKey(5), (64, 32))
    out = crossbar_linear(x, w, cfg=_cfg())
    assert out.dtype == jnp.bfloat16
