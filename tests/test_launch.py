"""Launch-path tests: the dry-run machinery end-to-end on a tiny mesh
(subprocess, because the 512-device XLA flag must be set before jax
init), sharding-rule unit tests, input specs."""

import json
import os
import subprocess
import sys

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, get_smoke
from repro.dist import sharding as shd
from repro.launch import steps as steps_mod

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ----------------------------- sharding rules --------------------------------


def test_spec_for_leaf_divisibility_fallback():
    mesh_shape = {"data": 16, "model": 16}
    # 15 heads do not divide 16 -> replicated, embed 960 divides -> sharded
    spec = shd.spec_for_leaf(("embed", "q_heads"), (960, 15 * 64),
                             shd.TRAIN_RULES, mesh_shape)
    assert spec == P("data", "model")
    spec = shd.spec_for_leaf(("heads", None, None), (15, 64, 64),
                             shd.TRAIN_RULES, mesh_shape)
    assert spec == P(None, None, None)


def test_experts_ep_rule():
    mesh_shape = {"data": 16, "model": 16}
    # 16 experts shard over model; 40 do not
    assert shd.spec_for_leaf(("experts", "embed", "mlp"), (16, 64, 64),
                             shd.TRAIN_RULES, mesh_shape)[0] == "model"
    assert shd.spec_for_leaf(("experts", "embed", "mlp"), (40, 64, 64),
                             shd.TRAIN_RULES, mesh_shape)[0] is None


def test_serve_rules_disable_fsdp():
    mesh_shape = {"data": 16, "model": 16}
    spec = shd.spec_for_leaf(("embed", "mlp"), (1024, 4096),
                             shd.SERVE_RULES, mesh_shape)
    assert spec == P(None, "model")


def test_axes_trees_match_param_trees():
    """Every arch: the logical-axes tree must be congruent with the param
    tree (same structure, rank-matching tuples)."""
    from repro.models import get_model
    for arch in ("smollm-360m", "xlstm-125m", "recurrentgemma-2b",
                 "granite-moe-3b-a800m", "seamless-m4t-medium", "qwen2-vl-2b"):
        cfg = get_smoke(arch)
        api = get_model(cfg)
        shapes = jax.eval_shape(lambda c=cfg, a=api: a.init(jax.random.PRNGKey(0), c))
        axes = api.axes(cfg)
        def chk(ax, sd):
            assert isinstance(ax, tuple) and len(ax) == len(sd.shape), (arch, ax, sd.shape)
        jax.tree.map(chk, axes, shapes, is_leaf=lambda x: isinstance(x, tuple))


# ------------------------------ input specs ----------------------------------


def test_input_specs_shapes():
    cfg = get_config("qwen2-72b")
    sp = steps_mod.input_specs(cfg, "train_4k")
    assert sp["tokens"].shape == (256, 4096)
    sp = steps_mod.input_specs(cfg, "prefill_32k")
    assert sp["tokens"].shape == (32, 32768)
    sp = steps_mod.input_specs(cfg, "decode_32k")
    assert sp["tokens"].shape == (128, 1)
    assert sp["caches"]["k"].shape == (80, 128, 8, 32768, 128)


def test_long_500k_gate():
    ok, _ = steps_mod.shape_applicable(get_config("qwen2-72b"), "long_500k")
    assert not ok
    ok, _ = steps_mod.shape_applicable(get_config("xlstm-125m"), "long_500k")
    assert ok
    ok, _ = steps_mod.shape_applicable(get_config("recurrentgemma-2b"), "long_500k")
    assert ok


def test_vlm_and_encdec_specs_have_prefix():
    assert "prefix_embeds" in steps_mod.input_specs(
        get_config("qwen2-vl-2b"), "train_4k")
    assert "prefix_embeds" in steps_mod.input_specs(
        get_config("seamless-m4t-medium"), "prefill_32k")


# --------------------------- dry-run smoke (subprocess) ----------------------


@pytest.mark.parametrize("arch,shape,mesh", [
    ("smollm-360m", "train_4k", "multi"),
    ("granite-moe-3b-a800m", "decode_32k", "single"),
    ("xlstm-125m", "long_500k", "multi"),
])
def test_dryrun_smoke_cell(tmp_path, arch, shape, mesh):
    """Full launch path (mesh, shardings, lower, compile, roofline) on a
    tiny mesh with reduced configs."""
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
         "--shape", shape, "--mesh", mesh, "--out", str(tmp_path), "--smoke"],
        cwd=REPO, capture_output=True, text=True, timeout=540,
        env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src")})
    assert "[ok]" in r.stdout, r.stdout + r.stderr[-2000:]
    cell = json.loads((tmp_path / f"{arch}__{shape}__{mesh}.json").read_text())
    assert cell["status"] == "ok"
    assert cell["roofline"]["dominant"] in ("compute", "memory", "collective")
    assert cell["hlo_cost"]["flops"] > 0
