"""Per-architecture smoke tests: reduced config of the same family, one
forward pass + one train-style grad step + one decode step on CPU;
assert shapes and no NaNs (brief requirement f)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke
from repro.models import get_model

B, T = 2, 16


def _batch(cfg, key):
    return jax.random.randint(key, (B, T), 0, cfg.vocab_size)


def _prefix(cfg, key):
    if cfg.family == "vlm":
        return jax.random.normal(key, (B, cfg.num_patches, cfg.d_model)) * 0.02
    if cfg.family == "encdec":
        return jax.random.normal(key, (B, T, cfg.d_model)) * 0.02
    return None


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch):
    cfg = get_config(arch)
    spec = {
        "xlstm-125m": (12, 768, 4, 4, 0, 50304),
        "nemotron-4-15b": (32, 6144, 48, 8, 24576, 256000),
        "qwen2-72b": (80, 8192, 64, 8, 29568, 152064),
        "qwen1.5-32b": (64, 5120, 40, 40, 27392, 152064),
        "smollm-360m": (32, 960, 15, 5, 2560, 49152),
        "seamless-m4t-medium": (12, 1024, 16, 16, 4096, 256206),
        "granite-moe-3b-a800m": (32, 1536, 24, 8, 512, 49155),
        "phi3.5-moe-42b-a6.6b": (32, 4096, 32, 8, 6400, 32064),
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
        "qwen2-vl-2b": (28, 1536, 12, 2, 8960, 151936),
    }[arch]
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == spec, f"{arch}: {got} != {spec}"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward(arch):
    cfg = get_smoke(arch)
    api = get_model(cfg)
    key = jax.random.PRNGKey(0)
    params = api.init(key, cfg)
    tokens = _batch(cfg, jax.random.PRNGKey(1))
    prefix = _prefix(cfg, jax.random.PRNGKey(2))
    logits, _ = api.apply(params, cfg, tokens, mode="train", prefix_embeds=prefix)
    t_total = T + (prefix.shape[1] if prefix is not None and cfg.family == "vlm" else 0)
    assert logits.shape == (B, t_total, cfg.vocab_size), logits.shape
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any()), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_grad(arch):
    cfg = get_smoke(arch)
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    tokens = _batch(cfg, jax.random.PRNGKey(1))
    prefix = _prefix(cfg, jax.random.PRNGKey(2))

    def loss_fn(p):
        logits, _ = api.apply(p, cfg, tokens, mode="train", prefix_embeds=prefix)
        logits = logits[:, -T:].astype(jnp.float32)
        logp = jax.nn.log_softmax(logits, axis=-1)
        tgt = jnp.roll(tokens, -1, axis=1)
        return -jnp.take_along_axis(logp, tgt[..., None], -1).mean()

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(float(loss)), arch
    gnorm = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))), grads))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0, arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_step(arch):
    """prefill then one decode step; logits finite, cache advances."""
    cfg = get_smoke(arch)
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    tokens = _batch(cfg, jax.random.PRNGKey(1))
    prefix = _prefix(cfg, jax.random.PRNGKey(2))

    if cfg.family == "encdec":
        logits, caches = api.apply(params, cfg, tokens, mode="prefill",
                                   prefix_embeds=prefix)
    else:
        logits, caches = api.apply(params, cfg, tokens, mode="prefill",
                                   prefix_embeds=prefix)
    assert caches is not None
    next_tok = jnp.argmax(logits[:, -1:].astype(jnp.float32), axis=-1)
    logits2, caches2 = api.apply(params, cfg, next_tok.astype(jnp.int32),
                                 mode="decode", caches=caches)
    assert logits2.shape == (B, 1, cfg.vocab_size)
    assert not bool(jnp.isnan(logits2.astype(jnp.float32)).any()), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_consistency(arch):
    """Teacher-forced logits at position t from (prefill of t+1 tokens) must
    match (prefill of t tokens, then decode of token t) -- the fundamental
    serving-correctness invariant.

    fp32 compute isolates the cache logic from bf16 noise; MoE runs
    dropless (high capacity factor) because capacity drops legitimately
    differ between the two prefill lengths."""
    import dataclasses
    cfg = dataclasses.replace(get_smoke(arch), compute_dtype="float32",
                              expert_capacity_factor=16.0)
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    tokens = _batch(cfg, jax.random.PRNGKey(1))
    prefix = _prefix(cfg, jax.random.PRNGKey(2))

    full_logits, _ = api.apply(params, cfg, tokens, mode="prefill",
                               prefix_embeds=prefix)
    _, caches = api.apply(params, cfg, tokens[:, :-1], mode="prefill",
                          prefix_embeds=prefix)
    step_logits, _ = api.apply(params, cfg, tokens[:, -1:], mode="decode",
                               caches=caches)
    np.testing.assert_allclose(
        np.asarray(full_logits[:, -1], np.float32),
        np.asarray(step_logits[:, 0], np.float32),
        rtol=2e-3, atol=2e-3)
