"""Substrate tests: optimizer, data determinism, train-loop loss descent,
checkpoint fault tolerance, gradient compression, serving engine."""

import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.data import DataConfig, SyntheticLM
from repro.dist import compression
from repro.optim import adamw
from repro.serve import Request, ServeEngine
from repro.train import checkpoint, elastic
from repro.train.train_loop import TrainConfig, make_train_step, StepWatchdog


# ------------------------------- optimizer ----------------------------------


def test_adamw_reduces_quadratic():
    params = {"w": jnp.array([3.0, -2.0, 5.0])}
    cfg = adamw.OptConfig(peak_lr=0.1, warmup_steps=0, total_steps=200,
                          weight_decay=0.0)
    state = adamw.init(params)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}
        params, state, m = adamw.update(params, grads, state, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.3


def test_schedule_shape():
    cfg = adamw.OptConfig(peak_lr=1.0, warmup_steps=10, total_steps=100,
                          min_lr_ratio=0.1)
    lrs = [float(adamw.schedule(jnp.asarray(s), cfg)) for s in range(101)]
    assert lrs[0] == 0.0
    assert abs(lrs[10] - 1.0) < 1e-6
    assert lrs[100] == pytest.approx(0.1, rel=1e-3)
    assert all(b <= a + 1e-9 for a, b in zip(lrs[10:], lrs[11:]))  # decaying


def test_grad_clip():
    g = {"a": jnp.ones((4,)) * 100.0}
    clipped, norm = adamw.clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(200.0)
    assert float(adamw.global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


# --------------------------------- data -------------------------------------


def test_data_deterministic_and_restart_safe():
    cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=4, seed=7)
    p1, p2 = SyntheticLM(cfg), SyntheticLM(cfg)
    b1, b2 = p1.batch(42), p2.batch(42)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(p1.batch(43)["tokens"], b1["tokens"])


def test_data_host_slicing_partitions_batch():
    cfg = DataConfig(vocab_size=50, seq_len=8, global_batch=8, seed=1)
    p = SyntheticLM(cfg)
    full = p.batch(5)["tokens"]
    parts = [p.host_slice(5, h, 4)["tokens"] for h in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts), full)


def test_targets_are_shifted_tokens():
    cfg = DataConfig(vocab_size=50, seq_len=8, global_batch=2, seed=1)
    b = SyntheticLM(cfg).batch(0)
    assert b["tokens"].shape == b["targets"].shape == (2, 8)


# ------------------------------ train loop ----------------------------------


def _tiny_train(arch="smollm-360m", accum=1, compress=False, steps=12):
    cfg = dataclasses.replace(get_smoke(arch), remat="none")
    tcfg = TrainConfig(
        opt=adamw.OptConfig(peak_lr=5e-3, warmup_steps=2, total_steps=100),
        accum_steps=accum, compress_grads=compress, loss_chunk=8)
    init_state, train_step = make_train_step(cfg, tcfg)
    state = init_state(jax.random.PRNGKey(0))
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                                  global_batch=4, seed=3, structure=0.95))
    step_j = jax.jit(train_step)
    losses = []
    for s in range(steps):
        b = {k: jnp.asarray(v) for k, v in data.batch(s).items()}
        state, metrics = step_j(state, b)
        losses.append(float(metrics["loss"]))
    return losses, state


def test_train_loss_decreases():
    losses, _ = _tiny_train()
    assert np.isfinite(losses).all()
    assert np.mean(losses[-3:]) < np.mean(losses[:3]) - 0.2, losses


def test_train_grad_accum_matches_full_batch():
    """accum=2 over the same global batch gives (near-)identical first-step
    grads to accum=1 -- linearity of gradient averaging."""
    l1, _ = _tiny_train(accum=1, steps=3)
    l2, _ = _tiny_train(accum=2, steps=3)
    assert l1[0] == pytest.approx(l2[0], rel=1e-4)
    assert l1[2] == pytest.approx(l2[2], rel=0.05)


def test_train_with_compression_still_learns():
    losses, state = _tiny_train(compress=True, steps=12)
    assert np.mean(losses[-3:]) < np.mean(losses[:3]) - 0.1, losses
    assert "ef" in state


def test_train_moe_arch_runs():
    losses, _ = _tiny_train(arch="granite-moe-3b-a800m", steps=4)
    assert np.isfinite(losses).all()


def test_watchdog_flags_stragglers():
    w = StepWatchdog(factor=3.0)
    for _ in range(10):
        assert not w.observe(0, 1.0)
    assert w.observe(11, 10.0)
    assert len(w.flagged) == 1


# ------------------------------ checkpointing -------------------------------


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3), "b": {"c": jnp.ones((4,))}}
    checkpoint.save(str(tmp_path), 7, tree)
    step, restored = checkpoint.restore_latest(str(tmp_path), like=tree)
    assert step == 7
    np.testing.assert_array_equal(restored["a"], tree["a"])
    np.testing.assert_array_equal(restored["b"]["c"], tree["b"]["c"])


def test_checkpoint_uncommitted_ignored(tmp_path):
    tree = {"a": jnp.ones((2,))}
    checkpoint.save(str(tmp_path), 1, tree)
    # Simulate a crash mid-write at step 2: directory without marker.
    os.makedirs(tmp_path / "step_000000002")
    step, _ = checkpoint.restore_latest(str(tmp_path), like=tree)
    assert step == 1


def test_checkpoint_corruption_fallback(tmp_path):
    tree = {"a": jnp.arange(4.0)}
    checkpoint.save(str(tmp_path), 1, tree)
    checkpoint.save(str(tmp_path), 2, jax.tree.map(lambda x: x + 1, tree))
    # Corrupt step 2's payload.
    victim = tmp_path / "step_000000002" / "arr_0.npy"
    victim.write_bytes(b"garbage")
    step, restored = checkpoint.restore_latest(str(tmp_path), like=tree)
    assert step == 1
    np.testing.assert_array_equal(restored["a"], tree["a"])


def test_checkpoint_gc_keeps_last_k(tmp_path):
    tree = {"a": jnp.zeros(2)}
    for s in range(5):
        checkpoint.save(str(tmp_path), s, tree, keep=2)
    assert checkpoint.list_steps(str(tmp_path)) == [3, 4]


def test_async_checkpointer(tmp_path):
    ck = checkpoint.AsyncCheckpointer(str(tmp_path))
    tree = {"w": jnp.ones((3, 3))}
    ck.submit(5, tree)
    ck.close()
    step, restored = checkpoint.restore_latest(str(tmp_path), like=tree)
    assert step == 5
    np.testing.assert_array_equal(restored["w"], tree["w"])


def test_train_restart_bitwise_resume(tmp_path):
    """Checkpoint at step 6, keep training to 9; restart from the checkpoint
    and replay -- losses must match exactly (deterministic pipeline +
    stateless schedule)."""
    cfg = dataclasses.replace(get_smoke("smollm-360m"), remat="none")
    tcfg = TrainConfig(opt=adamw.OptConfig(peak_lr=1e-3, warmup_steps=2,
                                           total_steps=50))
    init_state, train_step = make_train_step(cfg, tcfg)
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                                  global_batch=2, seed=5))
    step_j = jax.jit(train_step)

    state = init_state(jax.random.PRNGKey(0))
    for s in range(6):
        state, _ = step_j(state, jax.tree.map(jnp.asarray, data.batch(s)))
    checkpoint.save(str(tmp_path), 6, state)
    cont = []
    for s in range(6, 9):
        state, m = step_j(state, jax.tree.map(jnp.asarray, data.batch(s)))
        cont.append(float(m["loss"]))

    _, state2 = checkpoint.restore_latest(str(tmp_path), like=init_state(jax.random.PRNGKey(0)))
    state2 = jax.tree.map(jnp.asarray, state2)
    resumed = []
    for s in range(6, 9):
        state2, m = step_j(state2, jax.tree.map(jnp.asarray, data.batch(s)))
        resumed.append(float(m["loss"]))
    np.testing.assert_allclose(cont, resumed, rtol=1e-6)


# ------------------------------ compression ---------------------------------


def test_int8_roundtrip_error_bounded():
    x = jax.random.normal(jax.random.PRNGKey(0), (1000,))
    q, s = compression.quantize_int8(x)
    err = jnp.abs(compression.dequantize_int8(q, s) - x).max()
    assert float(err) <= float(s) * 0.5 + 1e-6


def test_error_feedback_is_unbiased_over_time():
    """Sum of EF-compressed grads converges to the sum of true grads."""
    g = {"w": jnp.full((16,), 0.003)}  # much smaller than a single int8 step
    ef = compression.init_error_feedback(g)
    total = jnp.zeros((16,))
    for _ in range(50):
        deq, ef = compression.compress_decompress_with_ef(g, ef)
        total = total + deq["w"]
    np.testing.assert_allclose(total, 0.003 * 50 * jnp.ones(16), rtol=0.05)


# -------------------------------- elastic -----------------------------------


def test_plan_remesh_accounting():
    shapes = {"w": jax.ShapeDtypeStruct((128, 128), jnp.float32)}
    plan = elastic.plan_remesh(shapes, {"pod": 2, "data": 16, "model": 16},
                               {"data": 16, "model": 16})
    assert plan["state_bytes"] == 128 * 128 * 4
    assert plan["old_devices"] == 512 and plan["new_devices"] == 256
    assert plan["moved_bytes_typical"] == plan["state_bytes"] // 2


# --------------------------------- serving ----------------------------------


def test_serve_engine_generates():
    cfg = get_smoke("smollm-360m")
    from repro.models import get_model
    params = get_model(cfg).init(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params, max_batch=2)
    reqs = [Request(rid=i, prompt=np.arange(4 + i) % cfg.vocab_size,
                    max_new_tokens=5) for i in range(3)]
    out = eng.generate(reqs)
    assert all(r.done for r in out)
    assert all(len(r.out_tokens) == 5 for r in out)
    assert all(0 <= t < cfg.vocab_size for r in out for t in r.out_tokens)


def test_serve_greedy_deterministic():
    cfg = get_smoke("smollm-360m")
    from repro.models import get_model
    params = get_model(cfg).init(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params, max_batch=1)
    mk = lambda: [Request(rid=0, prompt=np.arange(6) % cfg.vocab_size,
                          max_new_tokens=6)]
    a = eng.generate(mk())[0].out_tokens
    b = eng.generate(mk())[0].out_tokens
    assert a == b
