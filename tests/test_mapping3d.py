"""3D stack mapping: plane/layer accounting (§III.C), the paper's worked
example (§III.D), and the functional 3D MKMC simulation."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import crossbar as xbar
from repro.core import kn2row, mapping3d


def test_plan_3x3_fits_16_layers():
    """Paper: '16 layers are enough to handle a typical kernel size 3x3'."""
    p = mapping3d.plan_mapping(n=64, c=64, l1=3, l2=3, h=56, w=56,
                               spec=mapping3d.Stack3DSpec(layers=16))
    assert p.taps == 9
    assert p.layers_used == 10        # odd l^2 -> one dummy layer
    assert p.dummy_layers == 1
    assert p.voltage_planes == 6      # layers/2 + 1 (worked example: 6)
    assert p.current_planes == 5      # layers/2   (worked example: 5)
    assert p.passes == 1
    assert p.logical_cycles == 56 * 56


def test_plan_5x5_needs_two_passes():
    """Paper: smaller stacks 'must repeat the computation more than twice';
    16 layers handle 5x5 (26 layers incl. dummy) in two passes."""
    p = mapping3d.plan_mapping(n=32, c=16, l1=5, l2=5, h=28, w=28,
                               spec=mapping3d.Stack3DSpec(layers=16))
    assert p.taps == 25
    assert p.layers_used == 26
    assert p.passes == 2


def test_plan_even_taps_no_dummy():
    p = mapping3d.plan_mapping(n=8, c=8, l1=2, l2=2, h=4, w=4)
    assert p.layers_used == 4 and p.dummy_layers == 0


def test_plan_tiling():
    p = mapping3d.plan_mapping(n=300, c=200, l1=3, l2=3, h=10, w=10,
                               spec=mapping3d.Stack3DSpec(layers=16, wl_per_plane=128,
                                                          bl_per_plane=128))
    assert p.tiles_c == 2 and p.tiles_n == 3
    assert p.total_cycles == 1 * 2 * 3 * 100


def test_odd_even_layer_invariant():
    for l in (1, 2, 3, 4, 5, 7):
        p = mapping3d.plan_mapping(4, 4, l, l, 8, 8)
        assert p.layers_used % 2 == 0, "shared WL/BL structure needs even layers"
        assert p.layers_used - p.taps in (0, 1)


# ------------------- §III.D worked example: edge detection ------------------


def _paper_kernels():
    """Kernel 0: 4 negative taps, 5 non-negative (Laplacian-like);
    kernel 1: 1 negative tap, 8 non-negative.  Three channels, same values
    per channel -- exactly the paper's Fig. 7 setup."""
    k0 = np.array([[0, -1, 0], [-1, 4, -1], [0, -1, 0]], dtype=np.float32)
    k1 = np.array([[1, 1, 1], [1, 8, 1], [1, -1, 1]], dtype=np.float32)
    kernel = np.stack([k0, k1])[:, None, :, :].repeat(3, axis=1)  # (2, 3, 3, 3)
    return jnp.asarray(kernel)


def test_assign_layers_worked_example():
    kernel = _paper_kernels()
    assign = mapping3d.assign_layers(kernel)
    a0, a1 = assign
    # Kernel 0: 4 negative taps below the separation plane, 5 non-negative above.
    assert a0.n_neg_layers == 4 and a0.n_pos_layers == 5
    assert a0.separation_plane == 2          # paper: 'separation plane is voltage plane 2'
    assert a0.layers_needed == 10            # 9 taps + dummy
    # Kernel 1: 1 negative tap, 8 non-negative.
    assert a1.n_neg_layers == 1 and a1.n_pos_layers == 8
    assert a1.separation_plane == 1          # paper: 'separation plane is voltage plane 1'
    assert not a0.mixed_tap_ids and not a1.mixed_tap_ids


def test_assign_layers_mixed_sign_split():
    """Generalization: a tap with mixed-sign channels occupies a layer in
    BOTH groups (split), never exceeding the differential baseline's 2x."""
    k = np.zeros((1, 2, 1, 1), dtype=np.float32)
    k[0, 0, 0, 0] = 1.0
    k[0, 1, 0, 0] = -1.0
    (a,) = mapping3d.assign_layers(jnp.asarray(k))
    assert a.mixed_tap_ids == (0,)
    assert a.n_neg_layers == 1 and a.n_pos_layers == 1
    assert a.layers_needed == 2


def test_zero_taps_count_nonnegative():
    k = np.zeros((1, 3, 3, 3), dtype=np.float32)
    (a,) = mapping3d.assign_layers(jnp.asarray(k))
    assert a.n_neg_layers == 0 and a.n_pos_layers == 9


# ------------------------- functional 3D simulation -------------------------


def test_mkmc_3d_high_precision_matches_conv():
    img = jax.random.normal(jax.random.PRNGKey(0), (1, 3, 12, 12))
    ker = _paper_kernels()
    cfg = xbar.CrossbarConfig(weight_bits=14, dac_bits=14, adc_bits=18, g_on_off_ratio=1e9)
    got = mapping3d.mkmc_3d(img, ker, cfg=cfg)
    want = kn2row.conv2d_direct(img, ker)
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)


def test_mkmc_3d_ideal_is_exact():
    img = jax.random.normal(jax.random.PRNGKey(1), (2, 4, 9, 9))
    ker = jax.random.normal(jax.random.PRNGKey(2), (5, 4, 3, 3))
    cfg = xbar.CrossbarConfig(scheme="ideal")
    got = mapping3d.mkmc_3d(img, ker, cfg=cfg)
    want = kn2row.conv2d_direct(img, ker)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_mkmc_3d_channel_tiling():
    """c larger than wl_per_plane exercises the multi-crossbar digital
    accumulation path."""
    img = jax.random.normal(jax.random.PRNGKey(3), (1, 40, 8, 8))
    ker = jax.random.normal(jax.random.PRNGKey(4), (6, 40, 3, 3))
    spec = mapping3d.Stack3DSpec(layers=16, wl_per_plane=16, bl_per_plane=16)
    cfg = xbar.CrossbarConfig(weight_bits=14, dac_bits=14, adc_bits=18, g_on_off_ratio=1e9)
    got = mapping3d.mkmc_3d(img, ker, spec=spec, cfg=cfg)
    want = kn2row.conv2d_direct(img, ker)
    np.testing.assert_allclose(got, want, rtol=3e-2, atol=3e-2)
