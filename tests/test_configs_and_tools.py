"""Config invariants (property-tested) + the loop-aware HLO cost parser
on a synthetic module with known counts."""

import dataclasses

import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import ARCH_IDS, get_config, get_smoke
from repro.launch import hlo_cost


# ------------------------------ config invariants ----------------------------


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_config_invariants(arch):
    for cfg in (get_config(arch), get_smoke(arch)):
        assert cfg.num_heads % cfg.num_kv_heads == 0
        assert cfg.q_dim == cfg.num_heads * cfg.head_dim
        assert len(cfg.pattern()) == cfg.num_layers
        assert cfg.param_count() > 0
        if cfg.num_experts:
            assert cfg.param_count(active_only=True) < cfg.param_count()


def test_param_count_sanity():
    """Full-size param counts should be within ~35% of the names."""
    expect = {
        "qwen2-72b": 72e9, "qwen1.5-32b": 32e9, "nemotron-4-15b": 15e9,
        "smollm-360m": 360e6, "recurrentgemma-2b": 2.7e9,
        "phi3.5-moe-42b-a6.6b": 42e9, "qwen2-vl-2b": 2e9,
    }
    for arch, n in expect.items():
        got = get_config(arch).param_count()
        assert 0.6 * n < got < 1.6 * n, (arch, got, n)


def test_moe_active_params():
    cfg = get_config("phi3.5-moe-42b-a6.6b")
    active = cfg.param_count(active_only=True)
    assert 4e9 < active < 9e9, active  # ~6.6B active


@settings(max_examples=20, deadline=None)
@given(layers=st.integers(1, 8), heads=st.integers(1, 16),
       kv_div=st.integers(1, 4))
def test_config_property(layers, heads, kv_div):
    kv = max(1, heads // kv_div)
    if heads % kv:
        kv = heads
    cfg = dataclasses.replace(
        get_smoke("smollm-360m"), num_layers=layers, num_heads=heads,
        num_kv_heads=kv, head_dim=8)
    assert cfg.param_count() > 0
    assert len(cfg.pattern()) == layers


# ------------------------------- hlo_cost parser -----------------------------


SYNTH = """HloModule synth, entry_computation_layout={()->f32[]}

%body (p: (s32[], f32[128,128])) -> (s32[], f32[128,128]) {
  %p = (s32[], f32[128,128]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[128,128]{1,0} get-tuple-element(%p), index=1
  %d = f32[128,128]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[128,128]{1,0} all-reduce(%d), replica_groups=[16,16]<=[256], to_apply=%add
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[128,128]{1,0}) tuple(%ni, %ar)
}

%cond (p2: (s32[], f32[128,128])) -> pred[] {
  %p2 = (s32[], f32[128,128]{1,0}) parameter(0)
  %i2 = s32[] get-tuple-element(%p2), index=0
  %n = s32[] constant(10)
  ROOT %lt = pred[] compare(%i2, %n), direction=LT
}

ENTRY %main () -> f32[] {
  %init = (s32[], f32[128,128]{1,0}) tuple()
  %w = (s32[], f32[128,128]{1,0}) while(%init), condition=%cond, body=%body
  ROOT %r = f32[] constant(0)
}
"""


def test_hlo_cost_counts_loop_trips():
    r = hlo_cost.analyze(SYNTH)
    # dot: 2 * 128*128 * 128 flops, x 10 trips (+ small elementwise adds)
    dot_flops = 2 * 128 * 128 * 128 * 10
    assert dot_flops <= r["flops"] <= dot_flops * 1.05, r["flops"]
    # all-reduce: 128*128*4 bytes * 2(k-1)/k with k=16, x 10 trips
    expect = 128 * 128 * 4 * 2 * 15 / 16 * 10
    assert abs(r["coll_link_bytes"] - expect) / expect < 0.01
    assert r["while_trips"] == {"body": 10}


def test_hlo_cost_zero_cost_ops_free():
    r = hlo_cost.analyze(SYNTH)
    # bytes: only the dot (operands+result); tuples/GTE/parameters free.
    dot_bytes = 3 * 128 * 128 * 4 * 10 + 2 * 128 * 128 * 4 * 10  # dot + AR rw
    assert r["bytes_hbm"] <= dot_bytes * 1.05
