"""Model component tests: chunked attention vs direct softmax, mLSTM
chunkwise vs recurrent, RG-LRU associative vs sequential scan, MoE sorted
dispatch vs dense oracle, M-RoPE section plumbing."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models import attention, moe, rglru, xlstm
from repro.models.common import mrope_angles, rope_angles
from repro.configs import get_smoke


# --------------------------- chunked attention ------------------------------


def _direct_attention(q, k, v, causal=True, window=0):
    b, hq, sq, hd = q.shape
    _, hkv, skv, _ = k.shape
    g = hq // hkv
    qf = q.reshape(b, hkv, g, sq, hd).astype(jnp.float32)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qf, k.astype(jnp.float32)) * hd**-0.5
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= qpos - kpos < window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return o.reshape(b, hq, sq, hd)


@pytest.mark.parametrize("causal,window", [(True, 0), (True, 7), (False, 0)])
@pytest.mark.parametrize("qc,kc", [(4, 8), (16, 16), (5, 3)])
def test_chunked_attention_matches_direct(causal, window, qc, kc):
    key = jax.random.PRNGKey(0)
    b, hq, hkv, s, hd = 2, 6, 2, 23, 8
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, hq, s, hd))
    k = jax.random.normal(ks[1], (b, hkv, s, hd))
    v = jax.random.normal(ks[2], (b, hkv, s, hd))
    got = attention.chunked_attention(q, k, v, causal=causal, window=window,
                                      q_chunk=qc, kv_chunk=kc)
    want = _direct_attention(q, k, v, causal, window)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@settings(max_examples=15, deadline=None)
@given(s=st.integers(2, 40), qc=st.integers(1, 16), kc=st.integers(1, 16),
       seed=st.integers(0, 2**31 - 1))
def test_chunked_attention_property(s, qc, kc, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (1, 4, s, 8))
    k = jax.random.normal(ks[1], (1, 2, s, 8))
    v = jax.random.normal(ks[2], (1, 2, s, 8))
    got = attention.chunked_attention(q, k, v, q_chunk=qc, kv_chunk=kc)
    want = _direct_attention(q, k, v)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


# ------------------------------- mLSTM --------------------------------------


@pytest.mark.parametrize("chunk", [2, 3, 8, 16])
def test_mlstm_chunkwise_matches_recurrent(chunk):
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    b, h, t, dh = 2, 3, 16, 8
    q, k, v = (jax.random.normal(ks[j], (b, h, t, dh)) for j in range(3))
    i = jax.random.normal(ks[3], (b, h, t))
    f = jax.nn.log_sigmoid(jax.random.normal(ks[4], (b, h, t)) + 2.0)
    o_rec, s_rec = xlstm.mlstm_recurrent(q, k, v, i, f)
    o_chk, s_chk = xlstm.mlstm_chunkwise(q, k, v, i, f, chunk=chunk)
    np.testing.assert_allclose(o_rec, o_chk, rtol=2e-4, atol=2e-4)
    for a, b_ in zip(s_rec, s_chk):
        np.testing.assert_allclose(a, b_, rtol=2e-4, atol=2e-4)


def test_mlstm_state_handoff():
    """chunkwise(prefix) state feeds recurrent(suffix) exactly."""
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    b, h, t, dh = 1, 2, 12, 4
    q, k, v = (jax.random.normal(ks[j], (b, h, t, dh)) for j in range(3))
    i = jax.random.normal(ks[3], (b, h, t))
    f = jax.nn.log_sigmoid(jax.random.normal(ks[4], (b, h, t)))
    o_full, _ = xlstm.mlstm_recurrent(q, k, v, i, f)
    _, s = xlstm.mlstm_chunkwise(*(a[:, :, :8] for a in (q, k, v)),
                                 i[:, :, :8], f[:, :, :8], chunk=4)
    o_tail, _ = xlstm.mlstm_recurrent(*(a[:, :, 8:] for a in (q, k, v)),
                                      i[:, :, 8:], f[:, :, 8:], state=s)
    np.testing.assert_allclose(o_full[:, :, 8:], o_tail, rtol=2e-4, atol=2e-4)


@settings(max_examples=10, deadline=None)
@given(t=st.integers(1, 24), chunk=st.integers(1, 8), seed=st.integers(0, 2**31 - 1))
def test_mlstm_property(t, chunk, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    b, h, dh = 1, 2, 4
    q, k, v = (jax.random.normal(ks[j], (b, h, t, dh)) for j in range(3))
    i = jax.random.normal(ks[3], (b, h, t))
    f = jax.nn.log_sigmoid(jax.random.normal(ks[4], (b, h, t)))
    o_rec, _ = xlstm.mlstm_recurrent(q, k, v, i, f)
    o_chk, _ = xlstm.mlstm_chunkwise(q, k, v, i, f, chunk=chunk)
    np.testing.assert_allclose(o_rec, o_chk, rtol=1e-3, atol=1e-3)


# ------------------------------- RG-LRU -------------------------------------


def _rglru_sequential(x_gated, log_a, h0=None):
    b, t, w = x_gated.shape
    a = np.exp(np.asarray(log_a))
    b_term = np.sqrt(np.maximum(1 - a**2, 1e-12)) * np.asarray(x_gated)
    h = np.zeros((b, w)) if h0 is None else np.asarray(h0)
    out = []
    for i in range(t):
        h = a[:, i] * h + b_term[:, i]
        out.append(h.copy())
    return np.stack(out, axis=1)


@pytest.mark.parametrize("t", [1, 7, 32])
def test_rglru_scan_matches_sequential(t):
    ks = jax.random.split(jax.random.PRNGKey(3), 2)
    x = jax.random.normal(ks[0], (2, t, 8))
    log_a = -jnp.abs(jax.random.normal(ks[1], (2, t, 8))) * 0.5
    got = rglru.rglru_scan(x, log_a, None)
    want = _rglru_sequential(x, log_a)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_rglru_scan_with_initial_state():
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    x = jax.random.normal(ks[0], (1, 9, 4))
    log_a = -jnp.abs(jax.random.normal(ks[1], (1, 9, 4))) * 0.3
    h0 = jax.random.normal(ks[2], (1, 4))
    got = rglru.rglru_scan(x, log_a, h0)
    want = _rglru_sequential(x, log_a, h0)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_rglru_stability():
    """|a| < 1 by construction -> bounded state for bounded inputs."""
    cfg = get_smoke("recurrentgemma-2b")
    p = rglru.rglru_init(jax.random.PRNGKey(5), cfg)
    x = jax.random.normal(jax.random.PRNGKey(6), (1, 512, cfg.lru_width))
    out, h = rglru.rglru_apply(p, x, None)
    assert np.isfinite(np.asarray(out)).all()
    assert float(jnp.abs(out).max()) < 100.0


# --------------------------------- MoE ---------------------------------------


def test_moe_dispatch_matches_dense_oracle():
    cfg = dataclasses.replace(get_smoke("phi3.5-moe-42b-a6.6b"),
                              expert_capacity_factor=16.0)  # dropless
    p = moe.moe_init(jax.random.PRNGKey(7), cfg)
    x = jax.random.normal(jax.random.PRNGKey(8), (2, 12, cfg.d_model),
                          dtype=jnp.float32)
    got = moe.moe_apply(p, cfg, x)
    want = moe.moe_apply_dense_fallback(p, cfg, x)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_moe_capacity_drops_bounded():
    """With cf=1.0 drops may occur but output stays finite and the kept
    tokens match the oracle where no drop happened (coarse check)."""
    cfg = dataclasses.replace(get_smoke("granite-moe-3b-a800m"),
                              expert_capacity_factor=1.0)
    p = moe.moe_init(jax.random.PRNGKey(9), cfg)
    x = jax.random.normal(jax.random.PRNGKey(10), (2, 16, cfg.d_model))
    out = moe.moe_apply(p, cfg, x)
    assert np.isfinite(np.asarray(out, np.float32)).all()


def test_moe_aux_loss_positive():
    cfg = get_smoke("granite-moe-3b-a800m")
    p = moe.moe_init(jax.random.PRNGKey(11), cfg)
    x = jax.random.normal(jax.random.PRNGKey(12), (1, 32, cfg.d_model))
    store = []
    moe.moe_apply(p, cfg, x, aux_loss_store=store)
    assert len(store) == 1 and float(store[0]) >= 1.0 - 1e-3  # >= 1 at balance


# -------------------------------- M-RoPE -------------------------------------


def test_mrope_equals_rope_when_positions_agree():
    """If all three position streams are identical, M-RoPE == RoPE."""
    b, t, hd = 2, 10, 16
    pos = jnp.broadcast_to(jnp.arange(t)[None], (b, t))
    pos3 = jnp.broadcast_to(pos[None], (3, b, t))
    c1, s1 = rope_angles(pos, hd, 1e4)
    c3, s3 = mrope_angles(pos3, (2, 3, 3), hd, 1e4)
    np.testing.assert_allclose(c1, c3, rtol=1e-6)
    np.testing.assert_allclose(s1, s3, rtol=1e-6)


def test_mrope_sections_validated():
    pos3 = jnp.zeros((3, 1, 4), jnp.int32)
    with pytest.raises(ValueError):
        mrope_angles(pos3, (2, 2, 2), 16, 1e4)  # sums to 6 != 8
