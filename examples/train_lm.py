"""End-to-end training driver (deliverable b): train a reduced config for a
few hundred steps on CPU with checkpoint/restart, or pass --full on real
hardware.  Demonstrates: deterministic pipeline, async checkpointing,
restore-on-start, straggler watchdog, gradient compression.

Run (CPU, ~2 min):
  PYTHONPATH=src python examples/train_lm.py
Longer / other archs:
  PYTHONPATH=src python examples/train_lm.py --arch granite-moe-3b-a800m --steps 300
"""

import argparse
import sys

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--full", action="store_true",
                    help="full-size config (needs real accelerators)")
    args, rest = ap.parse_known_args()

    argv = ["--arch", args.arch, "--steps", str(args.steps),
            "--batch", "8", "--seq", "128", "--ckpt-dir", "/tmp/repro_ckpt",
            "--ckpt-every", "50", "--compress-grads"]
    if not args.full:
        argv.append("--smoke")
    argv += rest
    out = train_main(argv)
    first, last = out["losses"][0], out["final_loss"]
    print(f"\nloss {first:.3f} -> {last:.3f} over {args.steps} steps "
          f"({'improved' if last < first else 'NO IMPROVEMENT -- check config'})")
    if last >= first:
        sys.exit(1)


if __name__ == "__main__":
    main()
