"""Batched serving example (deliverable b): slot-packed prefill + decode
with KV / recurrent-state caches, greedy and sampled decoding, across
architecture families (dense KV cache, xLSTM O(1) state, RecurrentGemma
rotating-window cache).

Run:  PYTHONPATH=src python examples/serve_lm.py
"""

from repro.launch.serve import main as serve_main


def main():
    for arch in ("smollm-360m", "xlstm-125m", "recurrentgemma-2b"):
        print(f"\n=== {arch} (reduced config) ===")
        serve_main(["--arch", arch, "--smoke", "--requests", "4",
                    "--max-new", "12", "--batch", "2", "--prompt-len", "8"])


if __name__ == "__main__":
    main()
