"""Paper §III.D worked example: edge detection on a 3-channel image with
two kernels through a 10-layer 3D ReRAM stack (Fig. 7).

Kernel 0 (Laplacian-like): 4 negative taps, 5 non-negative
  -> 10-layer stack, separation at voltage plane 2, I_n over current
     planes {0,1}, I_p over {2,3,4}  (paper Fig. 7c)
Kernel 1: 1 negative tap, 8 non-negative
  -> separation at voltage plane 1, I_n over {0}, I_p over {1..4}
     (paper Fig. 7d)
The inverting op-amp (Fig. 7e) reads I2 = I_p - I_n.

Run:  PYTHONPATH=src python examples/edge_detect_crossbar.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (CrossbarConfig, Stack3DSpec, assign_layers,
                        conv2d_direct, mkmc_3d, opamp_difference)


def make_image(h=24, w=24):
    """Synthetic 3-channel image with a bright square (clean edges)."""
    img = np.zeros((1, 3, h, w), np.float32)
    img[:, :, 6:18, 6:18] = 1.0
    img += 0.05 * np.random.default_rng(0).normal(size=img.shape)
    return jnp.asarray(img)


def main():
    k0 = np.array([[0, -1, 0], [-1, 4, -1], [0, -1, 0]], np.float32)
    k1 = np.array([[1, 1, 1], [1, 8, 1], [1, -1, 1]], np.float32)
    kernel = jnp.asarray(np.stack([k0, k1])[:, None].repeat(3, 1))  # (2,3,3,3)

    # Fig. 6 flow: scan kernels, count negative/non-negative, place layers.
    for a in assign_layers(kernel):
        print(f"kernel {a.kernel_index}: {a.n_neg_layers} negative layers "
              f"below separation plane {a.separation_plane}, "
              f"{a.n_pos_layers} non-negative above "
              f"({a.layers_needed}-layer stack incl. dummy)")

    image = make_image()
    exact = conv2d_direct(image, kernel)
    analog = mkmc_3d(image, kernel, spec=Stack3DSpec(layers=10),
                     cfg=CrossbarConfig(weight_bits=8, dac_bits=8, adc_bits=12,
                                        g_on_off_ratio=1e9))
    rel = float(jnp.linalg.norm(analog - exact) / jnp.linalg.norm(exact))
    print(f"analog vs exact edge map: relative error {rel:.3%}")

    # Fig. 7e sanity: the op-amp difference identity.
    i_p, i_n = jnp.asarray([3.0, 1.0]), jnp.asarray([1.0, 0.25])
    print("op-amp I2 = I_p - I_n:", np.asarray(opamp_difference(i_p, i_n)))

    # ASCII render of kernel-0's edge map.
    edge = np.asarray(analog)[0, 0]
    lo, hi = np.percentile(edge, [5, 95])
    chars = " .:-=+*#%@"
    print("\nkernel-0 (Laplacian) edge map, analog path:")
    for row in edge[::2]:
        line = ""
        for v in row[::1]:
            t = 0.0 if hi == lo else min(max((v - lo) / (hi - lo), 0.0), 1.0)
            line += chars[int(t * (len(chars) - 1))]
        print("   " + line)


if __name__ == "__main__":
    main()
