"""Quickstart: the paper's algorithm end-to-end in five minutes on CPU.

1. kn2row MKMC convolution == direct convolution (the §III.B algorithm)
2. the same conv through the simulated 16-layer 3D ReRAM stack (§III.C)
3. the Pallas TPU kernel (interpret mode on CPU) -- the fused
   shift-GEMM with VMEM superimposition
4. the cost model's Fig-9 headline numbers vs the paper

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.core import (CrossbarConfig, PAPER_FIG9, Stack3DSpec, conv2d_direct,
                        conv2d_kn2row, evaluate_fig9, mkmc_3d, plan_mapping)
from repro.kernels import kn2row_conv


def main():
    key = jax.random.PRNGKey(0)
    image = jax.random.normal(key, (1, 16, 32, 32))          # (b, c, h, w)
    kernels = jax.random.normal(jax.random.fold_in(key, 1), (8, 16, 3, 3))

    # 1. kn2row == direct (the paper's 1x1-decomposition, §III.B)
    out_kn2row = conv2d_kn2row(image, kernels)
    out_direct = conv2d_direct(image, kernels)
    err = float(jnp.abs(out_kn2row - out_direct).max())
    print(f"[1] kn2row vs direct conv: max |diff| = {err:.2e}")

    # 2. through the simulated 3D ReRAM stack (8-bit DAC/weights, 12-bit ADC)
    plan = plan_mapping(8, 16, 3, 3, 32, 32, Stack3DSpec(layers=16))
    print(f"[2] 3D mapping: {plan.taps} taps -> {plan.layers_used} layers "
          f"({plan.dummy_layers} dummy), {plan.voltage_planes} voltage / "
          f"{plan.current_planes} current planes, {plan.total_cycles} cycles")
    out_analog = mkmc_3d(image, kernels,
                         cfg=CrossbarConfig(weight_bits=8, dac_bits=8,
                                            adc_bits=12, g_on_off_ratio=1e9))
    rel = float(jnp.linalg.norm(out_analog - out_direct)
                / jnp.linalg.norm(out_direct))
    print(f"    analog-path relative error = {rel:.3%} "
          f"(paper: 'same inference accuracy')")

    # 3. the Pallas kernel (TPU target, interpret-validated on CPU)
    out_kernel = kn2row_conv(image, kernels)
    err_k = float(jnp.abs(out_kernel - out_direct).max())
    print(f"[3] Pallas fused kn2row kernel: max |diff| = {err_k:.2e}")

    # 4. Fig 9 reproduction from the calibrated cost model
    r = evaluate_fig9()
    p = PAPER_FIG9
    print("[4] Fig 9 (model vs paper):")
    print(f"    speedup  vs 2D/CPU/GPU: {r.speedup_vs_2d:.2f}/"
          f"{r.speedup_vs_cpu:.0f}/{r.speedup_vs_gpu:.1f} "
          f"(paper {p.speedup_vs_2d}/{p.speedup_vs_cpu}/{p.speedup_vs_gpu})")
    print(f"    energy   vs 2D/CPU/GPU: {r.energy_saving_vs_2d:.2f}/"
          f"{r.energy_saving_vs_cpu:.0f}/{r.energy_saving_vs_gpu:.0f} "
          f"(paper {p.energy_saving_vs_2d}/{p.energy_saving_vs_cpu}/"
          f"{p.energy_saving_vs_gpu})")


if __name__ == "__main__":
    main()
