"""Roofline table builder: reads results/dryrun/*.json (written by
repro.launch.dryrun) and renders the §Roofline table for EXPERIMENTS.md.

Per (arch x shape x mesh): the three terms in seconds, the dominant term,
MODEL_FLOPS, the useful-flops ratio, and a one-line 'what would move the
dominant term' note."""

from __future__ import annotations

import glob
import json
import os

ADVICE = {
    ("compute",): "raise MXU utilization: bigger per-chip tiles / fuse "
                  "elementwise into matmuls / drop causal-block waste",
    ("memory",): "cut HBM traffic: more fusion, bf16 residuals, larger "
                 "attention blocks, activation-recompute instead of spill",
    ("collective",): "re-shard to cut collectives: 2D-shard the weights, "
                     "overlap via async collectives, int8-compress the "
                     "cross-pod hop",
}


def load_cells(out_dir: str = "results/dryrun") -> list[dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def advice_for(cell: dict) -> str:
    dom = cell["roofline"]["dominant"]
    return ADVICE[(dom,)]


def table(cells: list[dict], mesh: str = "single") -> str:
    rows = [
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "MODEL_FLOPS | useful ratio | next move |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for c in cells:
        if c.get("mesh") != mesh:
            continue
        if c["status"] == "skipped":
            rows.append(f"| {c['arch']} | {c['shape']} | -- | -- | -- | "
                        f"skipped | -- | -- | {c['reason'][:60]} |")
            continue
        if c["status"] != "ok":
            rows.append(f"| {c['arch']} | {c['shape']} | -- | -- | -- | "
                        f"ERROR | -- | -- | {c['error'][:60]} |")
            continue
        r = c["roofline"]
        rows.append(
            f"| {c['arch']} | {c['shape']} | {r['t_compute_s']:.2e} | "
            f"{r['t_memory_s']:.2e} | {r['t_collective_s']:.2e} | "
            f"{r['dominant']} | {r['model_flops']:.2e} | "
            f"{r['useful_flops_ratio']:.2f} | {advice_for(c)[:70]} |")
    return "\n".join(rows)


def run() -> list[tuple[str, float, str]]:
    cells = load_cells()
    results = []
    for c in cells:
        tag = f"roofline/{c['arch']}/{c['shape']}/{c['mesh']}"
        if c["status"] != "ok":
            results.append((tag, 0.0, c["status"]))
            continue
        r = c["roofline"]
        results.append((
            tag, r["roofline_bound_s"] * 1e6,
            f"dom={r['dominant']};tc={r['t_compute_s']:.2e}"
            f";tm={r['t_memory_s']:.2e};tl={r['t_collective_s']:.2e}"
            f";useful={r['useful_flops_ratio']:.2f}"))
    return results


if __name__ == "__main__":
    cells = load_cells()
    print(table(cells, "single"))
    print()
    print(table(cells, "multi"))
