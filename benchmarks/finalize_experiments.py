"""Insert the regenerated roofline tables and the §Perf cell-C log into
EXPERIMENTS.md (between the marker comments)."""

import json
import os
import re

from . import roofline


def perf_cell_c_table() -> str:
    path = "results/perf/perf_recurrentgemma-2b__train_4k__single.json"
    if not os.path.exists(path):
        return "*(pending)*"
    rows = json.load(open(path))
    out = ["| stage | t_compute | t_memory | t_collective | bound | temp GiB |",
           "|---|---|---|---|---|---|"]
    for r in rows:
        out.append(
            f"| {r['stage']} | {r['t_compute_s']:.2f} | {r['t_memory_s']:.2f} "
            f"| {r['t_collective_s']:.2f} | {r['bound_s']:.2f} | "
            f"{r['temp_GiB']:.1f} |")
    return "\n".join(out)


def main():
    cells = roofline.load_cells()
    table_single = roofline.table(cells, "single")
    table_multi = roofline.table(cells, "multi")
    block = ("### Single-pod mesh (16x16 = 256 chips)\n\n" + table_single
             + "\n\n### Multi-pod mesh (2x16x16 = 512 chips)\n\n" + table_multi)

    md = open("EXPERIMENTS.md").read()
    md = re.sub(r"<!-- ROOFLINE_TABLE -->.*?(?=\n### Reading the table)",
                "<!-- ROOFLINE_TABLE -->\n" + block + "\n",
                md, flags=re.S)
    md = re.sub(r"<!-- PERF_CELL_C -->.*?(?=\n---)",
                "<!-- PERF_CELL_C -->\n" + perf_cell_c_table() + "\n",
                md, count=1, flags=re.S)
    open("EXPERIMENTS.md", "w").write(md)
    print("EXPERIMENTS.md updated:",
          len([c for c in cells if c.get("status") == "ok"]), "ok cells,",
          len([c for c in cells if c.get("status") == "skipped"]), "skips")


if __name__ == "__main__":
    main()
