"""Beyond-paper ablations on the crossbar signal chain:

  1. accuracy vs bit-width (weight/DAC/ADC) -- quantifies the paper's
     'same inference accuracy' claim as a function of precision budget
  2. separated-negative scheme vs differential-pair baseline: cell count
     and ADC-conversion accounting per MKMC layer (the paper's Challenge 3)
  3. stack depth vs end-to-end latency at fixed workload (extends Fig 8)
"""

import jax
import jax.numpy as jnp

from repro.core import (ConvLayer, CrossbarConfig, Stack3DSpec, cost_3d_reram,
                        crossbar_vmm, mkmc_3d, plan_mapping)
from repro.core.kn2row import conv2d_direct


def run() -> list[tuple[str, float, str]]:
    results = []
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (16, 128))
    w = jax.random.normal(jax.random.fold_in(key, 1), (128, 64)) * 0.05
    exact = x @ w
    for bits in (2, 4, 6, 8, 10):
        cfg = CrossbarConfig(weight_bits=bits, dac_bits=bits,
                             adc_bits=bits + 2, g_on_off_ratio=1e9)
        out = crossbar_vmm(x, w, cfg)
        rel = float(jnp.linalg.norm(out - exact) / jnp.linalg.norm(exact))
        results.append((f"ablation/bits={bits}", 0.0, f"rel_err={rel:.4f}"))

    # Negative-separation vs differential cell/ADC accounting.
    img = jax.random.normal(jax.random.fold_in(key, 2), (1, 8, 12, 12))
    ker = jax.random.normal(jax.random.fold_in(key, 3), (6, 8, 3, 3))
    plan = plan_mapping(6, 8, 3, 3, 12, 12)
    results.append((
        "ablation/neg_separation", 0.0,
        f"cells_separated={plan.memristors_used}"
        f";cells_differential={plan.memristors_differential}"
        f";saving={plan.memristors_differential / plan.memristors_used:.2f}x"))
    cfg = CrossbarConfig(weight_bits=8, dac_bits=8, adc_bits=12,
                         g_on_off_ratio=1e9)
    out = mkmc_3d(img, ker, cfg=cfg)
    ref = conv2d_direct(img, ker)
    rel = float(jnp.linalg.norm(out - ref) / jnp.linalg.norm(ref))
    results.append(("ablation/neg_separation_accuracy", 0.0, f"rel_err={rel:.4f}"))

    # Stack depth sweep at fixed 5x5 workload (needs 26 layers: deeper
    # stacks amortize passes, shallower repeat).
    wl = ConvLayer("alexnet_conv2", n=256, c=96, h=27, w=27, l=5)
    for layers in (8, 16, 26, 32):
        r = cost_3d_reram(wl, Stack3DSpec(layers=layers))
        results.append((f"ablation/5x5_layers={layers}", r.time_s * 1e6,
                        f"passes={r.detail['plan'].passes}"))
    return results


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us},{derived}")
