"""Live-measured benchmark (the paper's 'CPU platform' measurement,
§IV-A): kn2row [9] vs im2col vs XLA direct convolution on this host, on
down-scaled paper workloads.  Also measures the Pallas kernels in
interpret mode (correctness-path timing only -- interpret mode is not
representative of TPU performance; the dry-run supplies the TPU-side
numbers)."""

import time

import jax
import jax.numpy as jnp

from repro.core import conv2d_direct, conv2d_im2col, conv2d_kn2row

# Reduced-size stand-ins for paper workloads (CPU-friendly).
WORKLOADS = [
    ("alexnet_conv3_ds", 1, 64, 13, 13, 96, 3),
    ("vgg16_conv3_ds", 1, 64, 28, 28, 64, 3),
    ("googlenet_5x5_ds", 1, 16, 28, 28, 32, 5),
]


def _time(fn, *args, iters=5) -> float:
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    out.block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6  # us


def run() -> list[tuple[str, float, str]]:
    results = []
    for name, b, c, h, w, n, l in WORKLOADS:
        key = jax.random.PRNGKey(0)
        img = jax.random.normal(key, (b, c, h, w))
        ker = jax.random.normal(jax.random.fold_in(key, 1), (n, c, l, l))
        f_kn = jax.jit(conv2d_kn2row)
        f_im = jax.jit(conv2d_im2col)
        f_di = jax.jit(conv2d_direct)
        t_kn = _time(f_kn, img, ker)
        t_im = _time(f_im, img, ker)
        t_di = _time(f_di, img, ker)
        results.append((f"kn2row_cpu/{name}", t_kn,
                        f"im2col_us={t_im:.0f};direct_us={t_di:.0f}"
                        f";kn2row_vs_im2col={t_im / t_kn:.2f}x"))
    return results


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us},{derived}")
