"""Paper Table I: memory-technology comparison (DESTINY, 1 GB @ 32 nm).

Emits the transcribed table and checks the paper's qualitative claims
(ReRAM dominates eDRAM/SRAM; beats STT-RAM except write latency)."""

from repro.core import MEMORY_TABLE


def rows():
    out = []
    for tech, (we, re_, wl, rl) in MEMORY_TABLE.items():
        out.append(dict(tech=tech, write_energy_nJ=we, read_energy_nJ=re_,
                        write_latency_ns=wl, read_latency_ns=rl))
    return out


def run() -> list[tuple[str, float, str]]:
    results = []
    for r in rows():
        results.append((f"table1/{r['tech']}", r["read_latency_ns"] * 1e-3,
                        f"rd_nJ={r['read_energy_nJ']};wr_nJ={r['write_energy_nJ']}"
                        f";wr_ns={r['write_latency_ns']}"))
    rr = MEMORY_TABLE["ReRAM"]
    ok = all(rr[i] < MEMORY_TABLE["eDRAM"][i] for i in range(4))
    results.append(("table1/reram_beats_edram", 0.0, str(ok)))
    return results


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us},{derived}")
