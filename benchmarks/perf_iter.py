"""§Perf hillclimbing harness: re-lower a dry-run cell with config-knob
variants and report the roofline-term deltas vs the paper-faithful
baseline.

  PYTHONPATH=src python -m benchmarks.perf_iter --arch qwen2-72b \
      --shape train_4k --variant bf16_gather --variant flash_bf16 ...

Each --variant applies a named dataclasses.replace on the ModelConfig
(see VARIANTS); variants compose left-to-right.  Output: one CSV row per
cumulative stage with (t_compute, t_memory, t_collective, temp_GiB) so
EXPERIMENTS.md §Perf can quote before/after per hypothesis.
"""

from __future__ import annotations

import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

import argparse
import dataclasses
import json

from repro.configs import ARCH_IDS  # noqa: E402  (after XLA_FLAGS on purpose)


VARIANTS = {
    # cast the param stack to bf16 before the layer scan -> bf16 FSDP gathers
    "bf16_gather": dict(cast_params_pre_scan=True),
    # keep bf16 operands into the flash score dot; bf16 P into the PV dot
    "flash_bf16": dict(flash_bf16_operands=True, flash_bf16_p=True),
    # shrink flash blocks under the VMEM-residency threshold
    "small_blocks": dict(flash_q_chunk=128, flash_kv_chunk=256),
    "tiny_blocks": dict(flash_q_chunk=64, flash_kv_chunk=128),
    "big_blocks": dict(flash_q_chunk=1024, flash_kv_chunk=2048),
    # reshard batch over (pod, data, model) inside attention
    "attn_batch_shard": dict(attn_batch_shard=True),
    # shard-local MoE routing (groups aligned with the 32 batch shards)
    "moe_groups": dict(moe_dispatch_groups=32),
    # manual shard_map dispatch: batch axes manual, model auto (EP)
    "moe_shard_map": dict(moe_shard_map=True),
    # zero-pad MHA heads up to the model-axis size
    "pad_heads": dict(attn_pad_heads=True),
    # RG-LRU gate matmuls in bf16 / batch-resharded LRU branch
    "lru_bf16": dict(lru_bf16_gates=True),
    "lru_batch_shard": dict(lru_batch_shard=True),
    # remat policy alternatives
    "remat_dots": dict(remat="dots"),
    "remat_none": dict(remat="none"),
    # unroll instead of scan (HLO size vs pipelining tradeoff)
    "unroll": dict(scan_layers=False),
}


def run(arch: str, shape: str, variants: list[str], mesh: str = "single",
        out_dir: str | None = None) -> list[dict]:
    from repro.launch import dryrun as dr
    from repro.configs import get_config

    multi = mesh == "multi"
    rows = []

    base_cfg = get_config(arch)
    overrides: dict = {}
    stages = [("baseline", {})] + [(v, VARIANTS[v]) for v in variants]
    orig_get = dr.get_config
    try:
        for name, delta in stages:
            overrides.update(delta)
            cfg = dataclasses.replace(base_cfg, **overrides)
            dr.get_config = lambda _a, _c=cfg: _c
            cell = dr.run_cell(arch, shape, multi)
            r = cell.get("roofline", {})
            row = {
                "stage": name,
                "status": cell["status"],
                "t_compute_s": r.get("t_compute_s"),
                "t_memory_s": r.get("t_memory_s"),
                "t_collective_s": r.get("t_collective_s"),
                "dominant": r.get("dominant"),
                "bound_s": r.get("roofline_bound_s"),
                "useful": r.get("useful_flops_ratio"),
                "temp_GiB": (cell.get("memory", {}).get("temp_bytes", 0)
                             / 2**30),
                "overrides": dict(overrides),
            }
            rows.append(row)
            print(f"{name}: dom={row['dominant']} "
                  f"t=({row['t_compute_s']:.3e},{row['t_memory_s']:.3e},"
                  f"{row['t_collective_s']:.3e}) bound={row['bound_s']:.3e}s "
                  f"temp={row['temp_GiB']:.2f}GiB", flush=True)
    finally:
        dr.get_config = orig_get

    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        with open(os.path.join(out_dir,
                               f"perf_{arch}__{shape}__{mesh}.json"), "w") as f:
            json.dump(rows, f, indent=1)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", choices=("single", "multi"), default="single")
    ap.add_argument("--variant", action="append", default=[],
                    choices=tuple(VARIANTS))
    ap.add_argument("--out", default="results/perf")
    args = ap.parse_args()
    run(args.arch, args.shape, args.variant, args.mesh, args.out)


if __name__ == "__main__":
    main()
