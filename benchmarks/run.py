# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness entry point:

  table I  -> bench_memtable     fig 8 -> bench_layer_sweep
  fig 9    -> bench_fig9         §IV-A CPU measurement -> bench_kn2row
  roofline -> roofline (reads results/dryrun, skipped when absent)
"""

from __future__ import annotations

import sys
import traceback

from . import (bench_ablation, bench_fig9, bench_kn2row, bench_layer_sweep,
               bench_memtable, roofline)


def main() -> None:
    print("name,us_per_call,derived")
    modules = [bench_memtable, bench_layer_sweep, bench_fig9, bench_kn2row,
               bench_ablation]
    if roofline.load_cells():
        modules.append(roofline)
    failures = 0
    for mod in modules:
        try:
            for name, us, derived in mod.run():
                print(f"{name},{us:.3f},{derived}")
        except Exception:
            failures += 1
            print(f"{mod.__name__},ERROR,", file=sys.stdout)
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == '__main__':
    main()
