"""Paper Fig. 8: normalized read/write latency & energy vs 3D layer count
(2 -> 16 layers, normalized to the 2-layer stack), from the calibrated
cost model.  Also sweeps the END-TO-END conv cost vs layer count to show
the paper's parallelism-vs-latency tradeoff (16 layers optimal for 3x3
given the DESTINY trend, §IV 'Configuration and Simulation')."""

import dataclasses

from repro.core import ConvLayer, cost_3d_reram, normalized_fig8
from repro.core.mapping3d import Stack3DSpec


def run() -> list[tuple[str, float, str]]:
    results = []
    for row in normalized_fig8():
        results.append((
            f"fig8/layers={row['layers']}", 0.0,
            f"rd_lat={row['read_latency']:.3f};wr_lat={row['write_latency']:.3f}"
            f";rd_en={row['read_energy']:.3f};wr_en={row['write_energy']:.3f}"))
    # End-to-end: time of a 3x3 conv layer vs stack depth (parallelism wins
    # until the taps fit, then deeper stacks only add access latency).
    wl = ConvLayer("vgg16_conv3_3", n=256, c=256, h=56, w=56, l=3)
    for layers in (2, 4, 8, 10, 16):
        spec = Stack3DSpec(layers=layers)
        r = cost_3d_reram(wl, spec)
        results.append((f"fig8/e2e_conv3x3_layers={layers}",
                        r.time_s * 1e6, f"energy_J={r.energy_j:.3e}"))
    return results


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us},{derived}")
