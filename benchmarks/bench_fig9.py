"""Paper Fig. 9: speedup and energy saving of 16-layer 3D ReRAM vs the
custom 2D baseline, CPU (i7-5700HQ) and GPU (GTX 1080 Ti), on the
VGG/AlexNet/GoogLeNet MKMC layer set.

Derived from the calibrated cost model; prints model ratios, the paper's
reported ratios, and the residuals.  The two energy-vs-CPU/GPU ratios
validate the calibration (energy_vs_gpu is the held-out prediction --
see core/costmodel.py docstring)."""

from repro.core import (PAPER_FIG9, PAPER_WORKLOADS, cost_2d_reram,
                        cost_3d_reram, cost_cpu, cost_gpu, evaluate_fig9)


def run() -> list[tuple[str, float, str]]:
    results = []
    r = evaluate_fig9()
    p = PAPER_FIG9
    pairs = [
        ("speedup_vs_2d", r.speedup_vs_2d, p.speedup_vs_2d),
        ("speedup_vs_cpu", r.speedup_vs_cpu, p.speedup_vs_cpu),
        ("speedup_vs_gpu", r.speedup_vs_gpu, p.speedup_vs_gpu),
        ("energy_vs_2d", r.energy_saving_vs_2d, p.energy_saving_vs_2d),
        ("energy_vs_cpu", r.energy_saving_vs_cpu, p.energy_saving_vs_cpu),
        ("energy_vs_gpu", r.energy_saving_vs_gpu, p.energy_saving_vs_gpu),
    ]
    for name, model, paper in pairs:
        rel = abs(model - paper) / paper
        results.append((f"fig9/{name}", 0.0,
                        f"model={model:.2f};paper={paper:.2f};rel_err={rel:.3f}"))
    # Per-layer breakdown (the paper aggregates; we expose the detail).
    for wl in PAPER_WORKLOADS:
        r3 = cost_3d_reram(wl)
        r2 = cost_2d_reram(wl)
        rc, rg = cost_cpu(wl), cost_gpu(wl)
        results.append((
            f"fig9/layer/{wl.name}", r3.time_s * 1e6,
            f"su2d={r2.time_s / r3.time_s:.2f};sucpu={rc.time_s / r3.time_s:.0f}"
            f";sugpu={rg.time_s / r3.time_s:.1f};en2d={r2.energy_j / r3.energy_j:.2f}"))
    return results


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us},{derived}")
