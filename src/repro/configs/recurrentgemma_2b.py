"""recurrentgemma-2b [hybrid]: RG-LRU recurrent blocks + local attention,
1 attention : 2 recurrent pattern (Griffin).
[arXiv:2402.19427; hf]  26L d_model=2560 10H (MQA kv=1) d_ff=7680
vocab=256000, window 2048, head_dim 256."""

import dataclasses

from .base import ModelConfig

# Griffin pattern: (rec, rec, attn) repeating; 26 layers -> 18R + 8A.
_PATTERN = ("RRA" * 9)[:26]

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    head_dim=256,
    d_ff=7680,                       # 3x GeGLU expansion
    vocab_size=256000,
    mlp_type="geglu",
    layer_pattern=_PATTERN,
    attention_window=2048,
    conv_width=4,
    lru_width=2560,
    rope_theta=10_000.0,
    tie_embeddings=True,
    logit_softcap=30.0,
    scan_layers=False,               # heterogeneous pattern -> unrolled
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="recurrentgemma-2b-smoke", num_layers=6,
        layer_pattern="RRARRA", d_model=64, num_heads=4, num_kv_heads=1,
        head_dim=16, d_ff=128, vocab_size=128, lru_width=64,
        attention_window=8, max_target_len=64)
