"""qwen2-72b [dense]: GQA kv=8, QKV bias, SwiGLU.
[arXiv:2407.10671; hf]  80L d_model=8192 64H d_ff=29568 vocab=152064."""

import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-72b",
    family="dense",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=29568,
    vocab_size=152064,
    mlp_type="swiglu",
    qkv_bias=True,                   # Qwen2 QKV bias
    rope_theta=1_000_000.0,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="qwen2-72b-smoke", num_layers=2, d_model=64,
        num_heads=8, num_kv_heads=2, head_dim=8, d_ff=160, vocab_size=128,
        max_target_len=64)
