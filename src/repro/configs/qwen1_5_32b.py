"""qwen1.5-32b [dense]: near-MHA (kv=40), QKV bias, SwiGLU.
[hf:Qwen/Qwen1.5-0.5B; hf]  64L d_model=5120 40H d_ff=27392 vocab=152064."""

import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-32b",
    family="dense",
    num_layers=64,
    d_model=5120,
    num_heads=40,
    num_kv_heads=40,
    d_ff=27392,
    vocab_size=152064,
    mlp_type="swiglu",
    qkv_bias=True,
    rope_theta=1_000_000.0,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="qwen1.5-32b-smoke", num_layers=2, d_model=80,
        num_heads=4, num_kv_heads=4, head_dim=20, d_ff=160, vocab_size=128,
        max_target_len=64)
