"""Config system: one frozen dataclass drives every architecture family.

``ModelConfig`` is the single source of truth consumed by models/, dist/,
launch/ and the benchmarks.  Each assigned architecture ships a module in
``repro.configs`` exposing ``CONFIG`` (full size, dry-run only) and
``smoke_config()`` (reduced, runs on CPU in tests).
"""

from __future__ import annotations

import dataclasses
from typing import Literal

Family = Literal["dense", "moe", "xlstm", "hybrid", "encdec", "vlm"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    # identity
    name: str
    family: Family
    # trunk dimensions
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                      # 0 -> d_model // num_heads
    # MLP
    mlp_type: str = "swiglu"               # swiglu | geglu | relu2 | gelu | none
    # attention
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    attention_window: int = 0              # 0 -> full; >0 -> sliding window
    # heterogeneous layer patterns: "A"=attn+mlp, "R"=recurrent(RG-LRU),
    # "s"=sLSTM block, "m"=mLSTM block.  Empty -> homogeneous "A" stack.
    layer_pattern: str = ""
    # MoE
    num_experts: int = 0
    num_experts_per_token: int = 0
    expert_capacity_factor: float = 1.25
    router_aux_loss: float = 0.01
    # encoder-decoder
    num_encoder_layers: int = 0            # >0 -> enc-dec; num_layers = decoder
    # vlm
    mrope_sections: tuple[int, int, int] = (0, 0, 0)   # (t, h, w) rope splits
    num_patches: int = 0                   # vision stub: patch embeddings fed in
    # ssm / hybrid
    conv_width: int = 4
    lru_width: int = 0                     # 0 -> d_model
    mlstm_proj_factor: float = 2.0
    slstm_proj_factor: float = 4.0 / 3.0
    mlstm_chunk: int = 64                  # chunkwise-parallel chunk length
    # norms / embeddings
    norm_type: str = "rmsnorm"
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    logit_softcap: float = 0.0
    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    # training
    remat: str = "full"                    # none | dots | full
    # serving
    max_target_len: int = 8192             # KV-cache capacity for serve_step
    # distribution hints (see dist/sharding.py)
    shard_experts: bool = True             # EP over 'model' when divisible
    scan_layers: bool = True               # scan-over-layers vs unrolled
    # ---- perf knobs (EXPERIMENTS.md §Perf; all off = paper-faithful baseline)
    flash_bf16_operands: bool = False      # keep q/k bf16 into the score dot
    flash_bf16_p: bool = False             # cast exp(p) to bf16 for the PV dot
    cast_params_pre_scan: bool = False     # bf16-cast param stack BEFORE the
                                           # layer scan -> FSDP gathers bf16
    attn_batch_shard: bool = False         # reshard batch over (data x model)
                                           # inside attention (replicated-head
                                           # archs regain model-axis compute)
    flash_q_chunk: int = 512
    flash_kv_chunk: int = 1024
    moe_dispatch_groups: int = 0           # >0: shard-local routing/sort in G
                                           # groups (kills the global argsort
                                           # collectives; G = batch shards)
    moe_shard_map: bool = False            # manual shard_map dispatch (batch
                                           # axes manual, 'model' auto for EP)
    attn_pad_heads: bool = False           # zero-pad head count up to the
                                           # model-axis size (MHA archs whose
                                           # heads don't divide it -- 1.2x
                                           # padded compute vs Nx replication)
    lru_bf16_gates: bool = False           # RG-LRU gate matmuls in bf16
    lru_batch_shard: bool = False          # reshard batch over (pod,data,
                                           # model) for the LRU branch: gate
                                           # matmuls + scan go fully local

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.lru_width == 0:
            object.__setattr__(self, "lru_width", self.d_model)
        if self.num_heads % max(self.num_kv_heads, 1) != 0:
            raise ValueError(
                f"{self.name}: num_heads={self.num_heads} not divisible by "
                f"num_kv_heads={self.num_kv_heads}"
            )
        if self.layer_pattern and len(self.layer_pattern) != self.num_layers:
            raise ValueError(
                f"{self.name}: layer_pattern length {len(self.layer_pattern)} "
                f"!= num_layers {self.num_layers}"
            )

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def is_encdec(self) -> bool:
        return self.num_encoder_layers > 0

    def pattern(self) -> str:
        return self.layer_pattern or "A" * self.num_layers

    # ---------------- parameter counting (for roofline MODEL_FLOPS) ---------

    def _mlp_params(self) -> int:
        if self.mlp_type == "none" or self.d_ff == 0:
            return 0
        if self.mlp_type in ("swiglu", "geglu"):
            return 3 * self.d_model * self.d_ff
        return 2 * self.d_model * self.d_ff

    def _attn_params(self) -> int:
        return self.d_model * (self.q_dim + 2 * self.kv_dim) + self.q_dim * self.d_model

    def _block_params(self, kind: str) -> int:
        d = self.d_model
        if kind == "A":
            p = self._attn_params()
            if self.num_experts > 0:
                p += self.num_experts * 3 * d * self.d_ff + d * self.num_experts
            else:
                p += self._mlp_params()
            return p
        if kind == "R":  # RG-LRU temporal block + its own MLP
            w = self.lru_width
            p = 2 * d * w + d * w  # x/gate branches in + out proj
            p += self.conv_width * w + 2 * w * (w // max(self.num_heads, 1)) * self.num_heads
            p += self._mlp_params()
            return p
        if kind == "m":  # mLSTM block
            di = int(self.d_model * self.mlstm_proj_factor)
            p = 2 * d * di + di * d          # up (x,z) + down
            p += self.conv_width * di
            p += 3 * di * di + 2 * di        # q,k,v + gates (per-head scalars approx)
            return p
        if kind == "s":  # sLSTM block + GeGLU proj
            h = self.d_model
            p = 4 * d * h + 4 * h * (h // max(self.num_heads, 1)) * self.num_heads
            dff = int(h * self.slstm_proj_factor)
            p += 3 * h * dff
            return p
        raise ValueError(kind)

    def param_count(self, active_only: bool = False) -> int:
        """Total (or active, for MoE) non-embedding trunk params + embeddings."""
        n = 0
        for kind in self.pattern():
            if kind == "A" and self.num_experts > 0 and active_only:
                d = self.d_model
                n += self._attn_params() + d * self.num_experts
                n += self.num_experts_per_token * 3 * d * self.d_ff
            else:
                n += self._block_params(kind)
        if self.is_encdec:
            enc = self._attn_params() + self._mlp_params()
            dec_cross = self._attn_params()
            n += self.num_encoder_layers * enc + self.num_layers * dec_cross
        n += self.vocab_size * self.d_model * (1 if self.tie_embeddings else 2)
        return n
