"""granite-moe-3b-a800m [moe]: 40 experts top-8, tiny expert d_ff=512.
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]  32L d_model=1536 24H (kv=8)
vocab=49155.  NOTE: the assignment line says "MoE 40e top-8" while its
trailing comment says 32 experts; the structured spec (40e) wins here."""

import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    d_ff=512,                        # per-expert FFN width
    vocab_size=49155,
    mlp_type="swiglu",
    num_experts=40,
    num_experts_per_token=8,
    rope_theta=10_000.0,
    tie_embeddings=True,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="granite-moe-3b-a800m-smoke", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, head_dim=16, d_ff=32, vocab_size=128,
        num_experts=8, num_experts_per_token=2, max_target_len=64)
