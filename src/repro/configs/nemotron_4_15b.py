"""nemotron-4-15b [dense]: GQA kv=8, squared-ReLU MLP (non-gated).
[arXiv:2402.16819; unverified]  32L d_model=6144 48H d_ff=24576 vocab=256000."""

import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b",
    family="dense",
    num_layers=32,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=24576,
    vocab_size=256000,
    mlp_type="relu2",                # Nemotron squared-ReLU
    qkv_bias=False,
    rope_theta=10_000.0,
    norm_type="layernorm",           # Nemotron-4 uses LayerNorm
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="nemotron-4-15b-smoke", num_layers=2, d_model=96,
        num_heads=8, num_kv_heads=4, head_dim=12, d_ff=192, vocab_size=128,
        max_target_len=64)
