"""seamless-m4t-medium [audio]: encoder-decoder backbone; audio frontend
stubbed (precomputed frame embeddings via input_specs()).
[arXiv:2308.11596; hf]  12L d_model=1024 16H (kv=16) d_ff=4096 vocab=256206."""

import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="encdec",
    num_layers=12,                   # decoder layers
    num_encoder_layers=12,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=256206,
    mlp_type="gelu",                 # classic transformer FFN
    norm_type="layernorm",
    rope_theta=10_000.0,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="seamless-m4t-medium-smoke", num_layers=2,
        num_encoder_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=128, max_target_len=64)
