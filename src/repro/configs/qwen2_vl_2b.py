"""qwen2-vl-2b [vlm]: M-RoPE decoder backbone, dynamic-resolution vision
tower stubbed (precomputed patch embeddings via input_specs()).
[arXiv:2409.12191; hf]  28L d_model=1536 12H (GQA kv=2) d_ff=8960
vocab=151936, M-RoPE sections (16, 24, 24)."""

import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    d_ff=8960,
    vocab_size=151936,
    mlp_type="swiglu",
    qkv_bias=True,
    rope_theta=1_000_000.0,
    mrope_sections=(16, 24, 24),     # t/h/w splits of head_dim/2 = 64
    num_patches=1024,                # default vision-stub prefix length
    tie_embeddings=True,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="qwen2-vl-2b-smoke", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=128,
        mrope_sections=(2, 3, 3), num_patches=16, max_target_len=64)
