"""Architecture config registry: ``get_config(arch_id)`` / ``get_smoke(arch_id)``.

The ten assigned architectures (plus any local additions) register here;
``--arch <id>`` in the launchers resolves through this table.
"""

from __future__ import annotations

import importlib

from .base import ModelConfig

_MODULES = {
    "xlstm-125m": "xlstm_125m",
    "nemotron-4-15b": "nemotron_4_15b",
    "qwen2-72b": "qwen2_72b",
    "qwen1.5-32b": "qwen1_5_32b",
    "smollm-360m": "smollm_360m",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "phi3.5-moe-42b-a6.6b": "phi3_5_moe_42b_a6_6b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "qwen2-vl-2b": "qwen2_vl_2b",
}

ARCH_IDS = tuple(_MODULES)


def _mod(arch: str):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(f".{_MODULES[arch]}", __package__)


def get_config(arch: str) -> ModelConfig:
    return _mod(arch).CONFIG


def get_smoke(arch: str) -> ModelConfig:
    return _mod(arch).smoke_config()


def all_configs() -> dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
