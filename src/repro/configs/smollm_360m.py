"""smollm-360m [dense]: llama-arch small, GQA kv=5, tied embeddings.
[hf:HuggingFaceTB/SmolLM-135M; hf]  32L d_model=960 15H d_ff=2560 vocab=49152."""

import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m",
    family="dense",
    num_layers=32,
    d_model=960,
    num_heads=15,
    num_kv_heads=5,
    d_ff=2560,
    vocab_size=49152,
    mlp_type="swiglu",
    rope_theta=10_000.0,
    tie_embeddings=True,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="smollm-360m-smoke", num_layers=2, d_model=60,
        num_heads=3, num_kv_heads=1, head_dim=20, d_ff=128, vocab_size=128,
        max_target_len=64)
