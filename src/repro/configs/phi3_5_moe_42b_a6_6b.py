"""phi3.5-moe-42b-a6.6b [moe]: 16 experts top-2.
[hf:microsoft/Phi-3.5-MoE-instruct; hf]  32L d_model=4096 32H (kv=8)
d_ff=6400 vocab=32064."""

import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=6400,                       # per-expert FFN width
    vocab_size=32064,
    mlp_type="swiglu",
    num_experts=16,
    num_experts_per_token=2,
    rope_theta=10_000.0,
    norm_type="layernorm",           # Phi-3.5-MoE uses LayerNorm
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="phi3.5-moe-42b-a6.6b-smoke", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, head_dim=16, d_ff=64, vocab_size=128,
        num_experts=4, num_experts_per_token=2, max_target_len=64)
