"""xlstm-125m [ssm]: sLSTM + mLSTM blocks, 1:1 alternating.
[arXiv:2405.04517; unverified]  12L d_model=768 4H (GQA kv=4) d_ff=0
vocab=50304.  d_ff=0: the blocks carry their own up/down projections."""

import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="xlstm",
    num_layers=12,
    d_model=768,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    mlp_type="none",
    layer_pattern="ms" * 6,          # mLSTM / sLSTM alternating 1:1
    conv_width=4,
    mlstm_proj_factor=2.0,
    slstm_proj_factor=4.0 / 3.0,
    mlstm_chunk=64,
    norm_type="rmsnorm",
    tie_embeddings=True,
    scan_layers=False,               # heterogeneous pattern -> unrolled
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="xlstm-125m-smoke", num_layers=4, layer_pattern="msms",
        d_model=64, num_heads=4, num_kv_heads=4, vocab_size=128,
        mlstm_chunk=8, max_target_len=64)
