"""AdamW with warmup-cosine schedule and global-norm clipping.

Pure pytree implementation (no optax dependency): fp32 moments, decoupled
weight decay, per-step schedule.  The optimizer state is sharded like the
params (same PartitionSpecs), which is what ZeRO/FSDP wants.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    peak_lr: float = 3e-4
    min_lr_ratio: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip_norm: float = 1.0


def schedule(step: jax.Array, cfg: OptConfig) -> jax.Array:
    """Linear warmup -> cosine decay to min_lr_ratio * peak."""
    step = step.astype(jnp.float32)
    warm = cfg.peak_lr * step / max(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.peak_lr * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio)
                         * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init(params) -> dict:
    zeros = lambda p: jax.tree.map(
        lambda x: jnp.zeros(x.shape, jnp.float32), p)
    return {"m": zeros(params), "v": zeros(params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    sq = jax.tree.map(lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))), tree)
    return jnp.sqrt(jax.tree.reduce(jnp.add, sq))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def update(params, grads, state, cfg: OptConfig):
    """Returns (new_params, new_state, metrics)."""
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    if cfg.grad_clip_norm > 0:
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip_norm)
    else:
        gnorm = global_norm(grads)
    step = state["step"] + 1
    lr = schedule(step, cfg)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    new_m = jax.tree.map(lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g,
                         state["m"], grads)
    new_v = jax.tree.map(lambda v, g: cfg.b2 * v + (1 - cfg.b2) * jnp.square(g),
                         state["v"], grads)

    def upd(p, m, v):
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    new_params = jax.tree.map(upd, params, new_m, new_v)
    return new_params, {"m": new_m, "v": new_v, "step": step}, \
        {"lr": lr, "grad_norm": gnorm}
