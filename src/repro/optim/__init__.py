"""Optimizers."""
from . import adamw
