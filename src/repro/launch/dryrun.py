import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run (deliverable e).

For every (architecture x input shape) cell, build the production mesh,
attach shardings, ``.lower().compile()`` the right step function
(train_step / prefill_step / serve_step), and record:

  * memory_analysis()      -- per-device bytes: proves the cell fits
  * cost_analysis()        -- per-device HLO FLOPs / bytes accessed
  * collective bytes       -- parsed from the partitioned HLO, with ring
                              algorithm factors per collective kind
  * the three roofline terms (§Roofline) on v5e constants

Single-pod mesh (16, 16) = 256 chips feeds the roofline table; the
multi-pod mesh (2, 16, 16) = 512 chips proves the 'pod' axis shards.

Usage:
  python -m repro.launch.dryrun --arch qwen2-72b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out results/dryrun
"""

import argparse
import json
import re
import time
import traceback

import jax

from ..configs import ARCH_IDS, get_config
from ..launch import hlo_cost
from ..launch import steps as steps_mod
from ..launch.mesh import make_production_mesh
from ..train.train_loop import make_train_step

# ------------------------- TPU v5e roofline constants ------------------------

PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (~per chip, 1 link active)

_COLL_RE = re.compile(
    r"= (\([^)]*\)|\S+) (all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(")
_SHAPE_RE = re.compile(
    r"(f64|f32|bf16|f16|f8\w*|s64|s32|s16|s8|u64|u32|u16|u8|pred)\[([\d,]*)\]")
_GROUP_EXPL_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUP_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")

_BYTES = {"f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
          "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
          "s8": 1, "u8": 1, "pred": 1}
_BYTES_DEFAULT = 1


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d.strip():
            n *= int(d)
    return n * _BYTES.get(dtype, _BYTES_DEFAULT)


def collective_stats(hlo_text: str) -> dict:
    """Per-device collective link traffic from the partitioned HLO.

    The compiled HLO prints shapes on the RESULT only; per kind, ring-
    algorithm link bytes per device in terms of the result size R and
    group size k:
      all-gather      R(k-1)/k      (operand = R/k, sent k-1 times)
      reduce-scatter  R(k-1)        (operand = R*k scattered)
      all-reduce      2R(k-1)/k     (RS + AG phases)
      all-to-all      R(k-1)/k
      collective-permute  R
    """
    per_kind: dict[str, float] = {}
    per_kind_raw: dict[str, float] = {}
    for m in _COLL_RE.finditer(hlo_text):
        result, kind = m.group(1), m.group(2)
        nbytes = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(result))
        line_end = hlo_text.find("\n", m.end())
        line = hlo_text[m.start(): line_end if line_end > 0 else m.end() + 500]
        k = 2
        g = _GROUP_EXPL_RE.search(line)
        if g:
            k = len(g.group(1).split(","))
        else:
            g = _GROUP_IOTA_RE.search(line)
            if g:
                k = int(g.group(2))
        factor = {"all-reduce": 2 * (k - 1) / k,
                  "all-gather": (k - 1) / k,
                  "reduce-scatter": float(k - 1),
                  "all-to-all": (k - 1) / k,
                  "collective-permute": 1.0}[kind]
        per_kind[kind] = per_kind.get(kind, 0.0) + nbytes * factor
        per_kind_raw[kind] = per_kind_raw.get(kind, 0.0) + nbytes
    return {"link_bytes_per_device": sum(per_kind.values()),
            "result_bytes_by_kind": per_kind_raw,
            "by_kind": per_kind}


def model_flops(cfg, shape: str) -> float:
    """MODEL_FLOPS = 6*N*D (train) / 2*N*D (inference), N = active params."""
    seq, batch, kind = steps_mod.SHAPES[shape]
    n = cfg.param_count(active_only=cfg.num_experts > 0)
    tokens = batch * (seq if kind in ("train", "prefill") else 1)
    return (6.0 if kind == "train" else 2.0) * n * tokens


def roofline_terms(cell: dict, chips: int) -> dict:
    """Three-term roofline from the loop-aware HLO analysis (hlo_cost).

    All quantities are PER-DEVICE (post-SPMD program), so each term is
    per-device work / per-chip bandwidth -- identical to the brief's
    total-work / (chips x bw) formulation."""
    flops_dev = cell["hlo_cost"]["flops"]
    bytes_dev = cell["hlo_cost"]["bytes_hbm"]
    coll_dev = cell["hlo_cost"]["coll_link_bytes"]
    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_coll = coll_dev / ICI_BW
    dominant = max(("compute", t_compute), ("memory", t_memory),
                   ("collective", t_coll), key=lambda kv: kv[1])[0]
    mf = cell["model_flops"]
    useful = mf / (flops_dev * chips) if flops_dev else 0.0
    return {"t_compute_s": t_compute, "t_memory_s": t_memory,
            "t_collective_s": t_coll, "dominant": dominant,
            "model_flops": mf, "useful_flops_ratio": useful,
            "roofline_bound_s": max(t_compute, t_memory, t_coll)}


def run_cell(arch: str, shape: str, multi_pod: bool, *, donate: bool = True,
             smoke: bool = False) -> dict:
    if smoke:
        from ..configs import get_smoke
        import jax as _jax
        cfg0 = get_smoke(arch)
        steps_mod.SHAPES = {k: (64, 8, v[2]) for k, v in steps_mod.SHAPES.items()}
        axes = ("pod", "data", "model") if multi_pod else ("data", "model")
        shp = (2, 2, 2) if multi_pod else (2, 2)
        mesh = _jax.make_mesh(shp, axes,
                              axis_types=(_jax.sharding.AxisType.Auto,) * len(axes))
    else:
        cfg0 = get_config(arch)
        mesh = None
    ok, why = steps_mod.shape_applicable(cfg0, shape)
    if not ok:
        return {"arch": arch, "shape": shape,
                "mesh": "multi" if multi_pod else "single",
                "status": "skipped", "reason": why}

    if mesh is None:
        mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    seq, batch, kind = steps_mod.SHAPES[shape]
    cfg = steps_mod.decode_config(cfg0, shape)
    t0 = time.time()

    with jax.set_mesh(mesh):
        if kind == "train":
            tcfg = steps_mod.train_config_for(arch)
            _, train_step = make_train_step(cfg, tcfg)
            state_sd = steps_mod.state_specs(cfg, tcfg)
            state_sh = steps_mod.state_shardings(cfg, tcfg, mesh)
            batch_sd = steps_mod.input_specs(cfg, shape)
            batch_sh = steps_mod.batch_shardings(batch_sd, mesh)
            fn = jax.jit(train_step, in_shardings=(state_sh, batch_sh),
                         donate_argnums=(0,) if donate else ())
            lowered = fn.lower(state_sd, batch_sd)
        else:
            prefill_step, serve_step = steps_mod.make_steps(cfg)
            params_sd = steps_mod.param_specs(cfg)
            params_sh = steps_mod.serve_param_shardings(cfg, mesh)
            batch_sd = steps_mod.input_specs(cfg, shape)
            batch_sh = steps_mod.batch_shardings(batch_sd, mesh)
            step = prefill_step if kind == "prefill" else serve_step
            # Pin the output cache layout (seq-sharded; see dist/sharding):
            # without it XLA may replicate caches whose head count does not
            # divide the model axis (+38 GiB/device on qwen1.5 prefill).
            cache_out_sh = steps_mod.cache_out_shardings(cfg, shape, mesh)
            fn = jax.jit(step, in_shardings=(params_sh, batch_sh),
                         out_shardings=(None, cache_out_sh),
                         donate_argnums=(1,) if (donate and kind == "decode") else ())
            lowered = fn.lower(params_sd, batch_sd)

        compiled = lowered.compile()

    mem = compiled.memory_analysis()
    cost = dict(compiled.cost_analysis())
    hlo = compiled.as_text()
    coll = collective_stats(hlo)                 # text-level (loop-unaware)
    hc = hlo_cost.analyze(hlo)                   # loop-aware (authoritative)
    cell = {
        "arch": arch, "shape": shape,
        "mesh": "multi" if multi_pod else "single",
        "chips": chips, "status": "ok",
        "compile_s": round(time.time() - t0, 1),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "code_bytes": mem.generated_code_size_in_bytes,
        },
        # raw backend numbers kept for reference; the CPU backend counts
        # while bodies once, hence hlo_cost below is authoritative.
        "cost": {k: v for k, v in cost.items()
                 if k in ("flops", "bytes accessed", "transcendentals")},
        "collectives": coll,
        "hlo_cost": hc,
        "model_flops": model_flops(cfg0, shape),
    }
    cell["roofline"] = roofline_terms(cell, chips)
    if os.environ.get("DRYRUN_SAVE_HLO"):
        import gzip
        with gzip.open(os.environ["DRYRUN_SAVE_HLO"] +
                       f"/{arch}__{shape}__{cell['mesh']}.hlo.gz", "wt") as f:
            f.write(hlo)
    return cell


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=tuple(steps_mod.SHAPES))
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--no-donate", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced configs on a tiny mesh (CI validation of "
                         "the full launch path)")
    args = ap.parse_args()

    cells = []
    if args.all:
        for a in ARCH_IDS:
            for s in steps_mod.SHAPES:
                cells.append((a, s))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        cells.append((args.arch, args.shape))

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    os.makedirs(args.out, exist_ok=True)

    for arch, shape in cells:
        for multi in meshes:
            tag = f"{arch}__{shape}__{'multi' if multi else 'single'}"
            path = os.path.join(args.out, tag + ".json")
            if os.path.exists(path):
                print(f"[skip-cached] {tag}")
                continue
            try:
                cell = run_cell(arch, shape, multi, donate=not args.no_donate,
                                smoke=args.smoke)
            except Exception as e:  # a failing cell is a bug -- record it loudly
                cell = {"arch": arch, "shape": shape,
                        "mesh": "multi" if multi else "single",
                        "status": "error", "error": f"{type(e).__name__}: {e}",
                        "traceback": traceback.format_exc()[-4000:]}
            with open(path, "w") as f:
                json.dump(cell, f, indent=1)
            status = cell["status"]
            extra = ""
            if status == "ok":
                r = cell["roofline"]
                extra = (f" compile={cell['compile_s']}s "
                         f"dom={r['dominant']} "
                         f"t=({r['t_compute_s']:.2e},{r['t_memory_s']:.2e},"
                         f"{r['t_collective_s']:.2e})s "
                         f"temp={cell['memory']['temp_bytes']/2**30:.2f}GiB")
            elif status == "error":
                extra = " " + cell["error"][:160]
            print(f"[{status}] {tag}{extra}", flush=True)


if __name__ == "__main__":
    main()
