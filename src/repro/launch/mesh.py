"""Production mesh construction.

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod slice).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the 'pod' axis rides
the DCN and composes with 'data' for hierarchical gradient reduction.

A FUNCTION, not a module constant: importing this module must never touch
jax device state (the dry-run pins the device count before any jax init).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_smoke_mesh(shape=(1, 1), axes=("data", "model")):
    """Single-device mesh for CPU tests of the sharded code path."""
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
