"""Serving driver: batched prefill + decode with the slot engine.

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --smoke \
      --requests 6 --max-new 16
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..configs import ARCH_IDS, get_config, get_smoke
from ..models import get_model
from ..serve import Request, ServeEngine
from ..serve.engine import throughput_stats


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="smollm-360m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    params = get_model(cfg).init(jax.random.PRNGKey(args.seed), cfg)
    engine = ServeEngine(cfg, params, max_batch=args.batch, seed=args.seed)

    rng = np.random.default_rng(args.seed)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        size=(args.prompt_len,)).astype(np.int32),
                    max_new_tokens=args.max_new,
                    temperature=args.temperature)
            for i in range(args.requests)]
    t0 = time.time()
    out = engine.generate(reqs)
    dt = time.time() - t0
    total = sum(len(r.out_tokens) for r in out)
    stats = throughput_stats(total, dt)
    for r in out[:4]:
        print(f"req {r.rid}: {r.out_tokens[:12]}{'...' if len(r.out_tokens) > 12 else ''}")
    print(f"[serve] {stats['tokens']} tokens in {stats['seconds']:.2f}s "
          f"= {stats['tokens_per_s']:.1f} tok/s")
    return stats


if __name__ == "__main__":
    main()
