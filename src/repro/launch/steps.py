"""Step builders shared by the launchers and the dry-run: train_step /
prefill_step / serve_step with their input ShapeDtypeStructs and
shardings for a given (arch config x input shape x mesh).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig
from ..dist import sharding as shd
from ..models import get_model
from ..models.common import dtype_of
from ..optim import adamw
from ..train.train_loop import TrainConfig, make_train_step


# The assigned input-shape grid (brief): name -> (seq_len, global_batch, kind).
SHAPES = {
    "train_4k": (4096, 256, "train"),
    "prefill_32k": (32768, 32, "prefill"),
    "decode_32k": (32768, 128, "decode"),
    "long_500k": (524288, 1, "decode"),
}

# Sub-quadratic families run long_500k; pure full-attention archs skip it.
LONG_OK_FAMILIES = ("xlstm", "hybrid")

# Gradient-accumulation per arch for train_4k: keeps per-device activation
# memory inside HBM (microbatch must stay divisible by pod*data = 32).
TRAIN_ACCUM = {
    "qwen2-72b": 8, "qwen1.5-32b": 8, "nemotron-4-15b": 8,
    "phi3.5-moe-42b-a6.6b": 8, "granite-moe-3b-a800m": 2,
    "recurrentgemma-2b": 2, "qwen2-vl-2b": 2,
}


def shape_applicable(cfg: ModelConfig, shape: str) -> tuple[bool, str]:
    if shape == "long_500k" and cfg.family not in LONG_OK_FAMILIES:
        return False, ("full-attention arch: 500k dense KV decode fails the "
                       "sub-quadratic gate (DESIGN.md §Shape applicability)")
    return True, ""


def _token_specs(cfg: ModelConfig, seq: int, batch: int, *, targets: bool):
    d = {"tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32)}
    if targets:
        d["targets"] = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
    ct = dtype_of(cfg.compute_dtype)
    if cfg.family == "vlm":
        d["prefix_embeds"] = jax.ShapeDtypeStruct(
            (batch, cfg.num_patches, cfg.d_model), ct)
    elif cfg.family == "encdec":
        # Audio-frontend stub: ~4x downsampled frame embeddings.
        t_src = max(seq // 4, 16)
        d["prefix_embeds"] = jax.ShapeDtypeStruct(
            (batch, t_src, cfg.d_model), ct)
    return d


def input_specs(cfg: ModelConfig, shape: str) -> dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell --
    weak-type-correct, shardable, no device allocation."""
    seq, batch, kind = SHAPES[shape]
    if kind == "train":
        return _token_specs(cfg, seq, batch, targets=True)
    if kind == "prefill":
        return _token_specs(cfg, seq, batch, targets=False)
    # decode: one new token against a seq-long cache
    api = get_model(cfg)
    dec_cfg = decode_config(cfg, shape)
    if cfg.family == "encdec":
        caches = api.init_caches(dec_cfg, batch, seq, t_src=max(seq // 4, 16))
    else:
        caches = api.init_caches(dec_cfg, batch, seq)
    d = {"tokens": jax.ShapeDtypeStruct((batch, 1), jnp.int32),
         "caches": caches}
    return d


def decode_config(cfg: ModelConfig, shape: str) -> ModelConfig:
    seq, _, kind = SHAPES[shape]
    if kind == "decode" or kind == "prefill":
        # VLM prefill writes vision-prefix + text positions into the cache.
        extra = cfg.num_patches if cfg.family == "vlm" else 0
        return dataclasses.replace(cfg, max_target_len=seq + extra)
    return cfg


# ----------------------------- step functions --------------------------------


def make_steps(cfg: ModelConfig):
    api = get_model(cfg)

    def prefill_step(params, batch):
        logits, caches = api.apply(params, cfg, batch["tokens"], mode="prefill",
                                   prefix_embeds=batch.get("prefix_embeds"))
        return logits[:, -1], caches   # serving needs last-position logits only

    def serve_step(params, batch):
        logits, caches = api.apply(params, cfg, batch["tokens"], mode="decode",
                                   caches=batch["caches"])
        return logits[:, -1], caches

    return prefill_step, serve_step


def param_specs(cfg: ModelConfig):
    api = get_model(cfg)
    return jax.eval_shape(lambda: api.init(jax.random.PRNGKey(0), cfg))


def state_specs(cfg: ModelConfig, tcfg: TrainConfig):
    init_state, _ = make_train_step(cfg, tcfg)
    return jax.eval_shape(lambda: init_state(jax.random.PRNGKey(0)))


def state_shardings(cfg: ModelConfig, tcfg: TrainConfig, mesh: Mesh):
    """NamedShardings for the train state: params/m/v by the train rules,
    step replicated."""
    api = get_model(cfg)
    axes = api.axes(cfg)
    pshapes = param_specs(cfg)
    pspec = shd.param_pspecs(axes, pshapes, mesh, mode="train")
    state_spec = {
        "params": pspec,
        "opt": {"m": pspec, "v": pspec, "step": P()},
    }
    if tcfg.compress_grads:
        state_spec["ef"] = pspec
    return jax.tree.map(lambda s: NamedSharding(mesh, s), state_spec,
                        is_leaf=lambda x: isinstance(x, P))


def serve_param_shardings(cfg: ModelConfig, mesh: Mesh):
    api = get_model(cfg)
    pspec = shd.param_pspecs(api.axes(cfg), param_specs(cfg), mesh, mode="serve")
    return jax.tree.map(lambda s: NamedSharding(mesh, s), pspec,
                        is_leaf=lambda x: isinstance(x, P))


def batch_shardings(batch_specs, mesh: Mesh):
    def leaf(sd):
        if not hasattr(sd, "shape"):
            return NamedSharding(mesh, P())
        return None
    # tokens/targets/prefix: dim-0 batch sharding; caches: cache rules.
    out = {}
    for k, v in batch_specs.items():
        if k == "caches":
            out[k] = jax.tree.map(lambda s: NamedSharding(
                mesh, _one(shd.cache_pspecs(s, mesh))), v)
        else:
            out[k] = jax.tree.map(lambda s: NamedSharding(
                mesh, _one(shd.data_pspecs(s, mesh))), v)
    return out


def _one(x):
    # data_pspecs/cache_pspecs map over trees; leaves here are single specs
    return x if isinstance(x, P) else jax.tree.leaves(
        x, is_leaf=lambda y: isinstance(y, P))[0]


def cache_out_shardings(cfg: ModelConfig, shape: str, mesh: Mesh):
    """NamedShardings for the cache RETURNED by prefill/serve steps."""
    seq, batch, kind = SHAPES[shape]
    api = get_model(cfg)
    dec_cfg = decode_config(cfg, shape)
    if cfg.family == "encdec":
        spec_tree = api.init_caches(dec_cfg, batch, dec_cfg.max_target_len,
                                    t_src=max(seq // 4, 16))
    else:
        spec_tree = api.init_caches(dec_cfg, batch, dec_cfg.max_target_len)
    return jax.tree.map(
        lambda s: NamedSharding(mesh, _one(shd.cache_pspecs(s, mesh))),
        spec_tree)


def train_config_for(arch_name: str) -> TrainConfig:
    return TrainConfig(opt=adamw.OptConfig(),
                       accum_steps=TRAIN_ACCUM.get(arch_name, 1))
