"""Loop-aware cost analysis of compiled (post-SPMD) HLO text.

Why: ``compiled.cost_analysis()`` on the CPU backend counts while-loop
BODIES ONCE -- a scan-over-80-layers train step reports ~1/80th of its
FLOPs, and text-grepped collectives inside loops are similarly
undercounted.  This module parses the HLO module into computations,
resolves every while loop's trip count from its condition computation,
and accumulates FLOPs / HBM bytes / collective link-bytes with loop
multiplicity.

Conventions (documented for EXPERIMENTS.md):
  * FLOPs: dot = 2 * numel(result) * prod(contracting dims); elementwise
    and reductions counted as 1 flop per output element (VPU work, noise
    next to the MXU terms for these models).
  * HBM bytes per op = result bytes + operand bytes at the op's level;
    fusion internals are NOT descended for bytes (fused intermediates
    stay in registers/VMEM), but ARE descended for FLOPs.
    dynamic-update-slice counts 2x the update (in-place), dynamic-slice /
    gather count 2x the result.
  * Collectives: link bytes per device from result size R and group size
    k -- all-gather R(k-1)/k, reduce-scatter R(k-1), all-reduce 2R(k-1)/k,
    all-to-all R(k-1)/k, collective-permute R.
"""

from __future__ import annotations

import dataclasses
import math
import re

_DTYPE_BYTES = {"f64": 8, "s64": 8, "u64": 8, "c64": 8, "f32": 4, "s32": 4,
                "u32": 4, "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
                "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "pred": 1}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\([^()]*\)|\S+)\s+([\w\-]+)\((.*)$")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_COND_BODY_RE = re.compile(r"condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_GROUP_EXPL_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUP_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_CONST_RE = re.compile(r"constant\((\d+)\)")

_COLL_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")
_ZERO_COST_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
                  "bitcast", "iota", "after-all", "custom-call"}
# Ops that touch HBM even under TPU fusion (layout changes, data movement,
# windowed reads).  Bare elementwise ops fuse and are excluded.
_BYTES_OPS = {"copy", "transpose", "dynamic-slice", "dynamic-update-slice",
              "gather", "scatter", "pad", "concatenate", "slice", "reverse",
              "reduce", "reduce-window", "sort"}
# Fusion-residency threshold: the CPU backend emits one micro-fusion per op,
# so call-site accounting would model a fusion-less machine.  Instead,
# elementwise/fusion RESULTS below this size are treated as VMEM-resident
# (fused away on TPU, ~half of v5e's 128 MiB VMEM); larger results must
# spill to HBM on any backend and are charged once (write at production;
# reads are charged by the consuming dot/data-movement ops).
_FUSION_VMEM_BYTES = 64 * 2**20


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_numel(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        total += n
    return total


@dataclasses.dataclass
class Op:
    name: str
    type_str: str
    opcode: str
    rest: str   # everything after the opening paren of operands


@dataclasses.dataclass
class Computation:
    name: str
    ops: list[Op]
    symbols: dict[str, str]   # op name -> type string


def parse_module(hlo: str) -> tuple[dict[str, Computation], str]:
    comps: dict[str, Computation] = {}
    entry = ""
    current: Computation | None = None
    for line in hlo.splitlines():
        if current is None:
            m = _COMP_HDR_RE.match(line)
            if m and line.rstrip().endswith("{"):
                current = Computation(m.group(1), [], {})
                if line.startswith("ENTRY"):
                    entry = m.group(1)
            continue
        if line.startswith("}"):
            comps[current.name] = current
            current = None
            continue
        m = _OP_RE.match(line)
        if m:
            op = Op(m.group(1), m.group(2), m.group(3), m.group(4))
            current.ops.append(op)
            current.symbols[op.name] = op.type_str
    if current is not None:
        comps[current.name] = current
    return comps, entry


def _operand_names(rest: str) -> list[str]:
    # operands are up to the first close paren at depth 0
    depth = 1
    out = []
    token = ""
    for ch in rest:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
        token += ch
    for part in token.split(","):
        part = part.strip()
        if part.startswith("%"):
            out.append(part[1:])
    return out


def _trip_count(comps: dict[str, Computation], cond_name: str) -> int:
    cond = comps.get(cond_name)
    if cond is None:
        return 1
    best = 1
    for op in cond.ops:
        for m in _CONST_RE.finditer(op.rest + op.type_str):
            best = max(best, int(m.group(1)))
        if op.opcode == "constant":
            m = _CONST_RE.search("constant(" + op.rest)
            if m:
                best = max(best, int(m.group(1)))
    # scan condition computations may delegate compare to a fused computation
    for op in cond.ops:
        cm = _CALLS_RE.search(op.rest)
        if cm and cm.group(1) in comps:
            for sub in comps[cm.group(1)].ops:
                for m in _CONST_RE.finditer(sub.rest):
                    best = max(best, int(m.group(1)))
    return max(best, 1)


@dataclasses.dataclass
class CostTotals:
    flops: float = 0.0
    bytes_hbm: float = 0.0
    coll_link_bytes: float = 0.0
    coll_by_kind: dict = dataclasses.field(default_factory=dict)
    while_trips: dict = dataclasses.field(default_factory=dict)

    def add(self, other: "CostTotals", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes_hbm += other.bytes_hbm * mult
        self.coll_link_bytes += other.coll_link_bytes * mult
        for k, v in other.coll_by_kind.items():
            self.coll_by_kind[k] = self.coll_by_kind.get(k, 0.0) + v * mult


def _dot_flops(op: Op, comp: Computation) -> float:
    out_elems = _shape_numel(op.type_str)
    operands = _operand_names(op.rest)
    k = 1
    cm = _CONTRACT_RE.search(op.rest)
    if cm and operands:
        lhs_type = comp.symbols.get(operands[0], "")
        m = _SHAPE_RE.search(lhs_type)
        if m:
            dims = [int(d) for d in m.group(2).split(",") if d.strip()]
            for ci in cm.group(1).split(","):
                ci = ci.strip()
                if ci and int(ci) < len(dims):
                    k *= dims[int(ci)]
    return 2.0 * out_elems * k


def _coll_link_bytes(op: Op) -> tuple[float, str]:
    r = _shape_bytes(op.type_str)
    k = 2
    g = _GROUP_EXPL_RE.search(op.rest)
    if g:
        k = len(g.group(1).split(","))
    else:
        g = _GROUP_IOTA_RE.search(op.rest)
        if g:
            k = int(g.group(2))
    kind = next(c for c in _COLL_KINDS if op.opcode.startswith(c))
    factor = {"all-reduce": 2 * (k - 1) / k,
              "all-gather": (k - 1) / k,
              "reduce-scatter": float(k - 1),
              "all-to-all": (k - 1) / k,
              "collective-permute": 1.0}[kind]
    return r * factor, kind


def _op_bytes(op: Op, comp: Computation) -> float:
    if op.opcode in _ZERO_COST_OPS and op.opcode != "custom-call":
        return 0.0
    result = _shape_bytes(op.type_str)
    if op.opcode == "dynamic-update-slice":
        ops_ = _operand_names(op.rest)
        upd = _shape_bytes(comp.symbols.get(ops_[1], "")) if len(ops_) > 1 else 0
        return 2.0 * upd
    if op.opcode in ("dynamic-slice", "gather"):
        return 2.0 * result
    total = float(result)
    for name in _operand_names(op.rest):
        total += _shape_bytes(comp.symbols.get(name, ""))
    return total


def _comp_cost(comps: dict[str, Computation], name: str,
               memo: dict[str, CostTotals], totals_sink: CostTotals | None = None,
               ) -> CostTotals:
    if name in memo:
        return memo[name]
    comp = comps.get(name)
    total = CostTotals()
    if comp is None:
        memo[name] = total
        return total
    memo[name] = total  # guards (benign) recursion
    for op in comp.ops:
        if any(op.opcode.startswith(c) for c in _COLL_KINDS):
            b, kind = _coll_link_bytes(op)
            total.coll_link_bytes += b
            total.coll_by_kind[kind] = total.coll_by_kind.get(kind, 0.0) + b
            total.bytes_hbm += 2.0 * _shape_bytes(op.type_str)
            continue
        if op.opcode == "while":
            cb = _COND_BODY_RE.search(op.rest)
            if cb:
                trip = _trip_count(comps, cb.group(1))
                body = _comp_cost(comps, cb.group(2), memo)
                cond = _comp_cost(comps, cb.group(1), memo)
                total.add(body, trip)
                total.add(cond, trip)
                total.while_trips[cb.group(2)] = trip
            continue
        if op.opcode == "conditional":
            bm = _BRANCHES_RE.search(op.rest)
            if bm:
                branches = [b.strip().lstrip("%") for b in bm.group(1).split(",")]
                costs = [_comp_cost(comps, b, memo) for b in branches]
                if costs:
                    worst = max(costs, key=lambda c: c.flops + c.bytes_hbm)
                    total.add(worst)
            continue
        if op.opcode in ("fusion", "call", "map", "reduce", "reduce-window",
                         "scatter", "select-and-scatter", "sort"):
            # Bytes: result charged only when it exceeds the VMEM-residency
            # threshold (micro-fusions on the CPU backend otherwise model a
            # fusion-less machine); data-movement opcodes keep full
            # result+operand accounting.  FLOPs from inside (dots may hide
            # in fusion bodies) -- descend for flops only.
            if op.opcode in _BYTES_OPS:
                total.bytes_hbm += _op_bytes(op, comp)
            else:
                r = _shape_bytes(op.type_str)
                if r > _FUSION_VMEM_BYTES:
                    total.bytes_hbm += r
            names = _CALLS_RE.findall(op.rest)
            for sub in names:
                inner = _comp_cost(comps, sub, memo)
                total.flops += inner.flops
                total.coll_link_bytes += inner.coll_link_bytes
                for k, v in inner.coll_by_kind.items():
                    total.coll_by_kind[k] = total.coll_by_kind.get(k, 0.0) + v
            if not names:
                total.flops += _shape_numel(op.type_str)
            continue
        if op.opcode == "dot":
            total.flops += _dot_flops(op, comp)
            total.bytes_hbm += _op_bytes(op, comp)
            continue
        if op.opcode == "convolution":
            # rare in this codebase (models avoid lax.conv); approximate via
            # result numel x 2 x contracted size inferred from operands
            total.flops += 2.0 * _shape_numel(op.type_str)
            total.bytes_hbm += _op_bytes(op, comp)
            continue
        if op.opcode in _ZERO_COST_OPS:
            # tuple / get-tuple-element / parameter / bitcast: loop-carry
            # bookkeeping, no data movement (counting their "results" once
            # inflated loop bodies by the whole carry size per iteration).
            continue
        # Elementwise & friends: 1 flop per output element; bytes only for
        # genuine data movement or above-threshold spills.
        total.flops += _shape_numel(op.type_str)
        if op.opcode in _BYTES_OPS:
            total.bytes_hbm += _op_bytes(op, comp)
        else:
            r = _shape_bytes(op.type_str)
            if r > _FUSION_VMEM_BYTES:
                total.bytes_hbm += r
    return total


def analyze(hlo_text: str) -> dict:
    comps, entry = parse_module(hlo_text)
    memo: dict[str, CostTotals] = {}
    total = _comp_cost(comps, entry, memo)
    return {
        "flops": total.flops,
        "bytes_hbm": total.bytes_hbm,
        "coll_link_bytes": total.coll_link_bytes,
        "coll_by_kind": dict(total.coll_by_kind),
        "num_computations": len(comps),
        "while_trips": dict(total.while_trips),
    }
