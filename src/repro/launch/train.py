"""Training driver: end-to-end on whatever devices exist (CPU smoke,
single pod, or multi-pod -- same code path).

  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m --smoke \
      --steps 50 --batch 4 --seq 128 --ckpt-dir /tmp/ckpt

Production posture: deterministic pipeline keyed by step (restart-safe),
async checkpointing every --ckpt-every steps, straggler watchdog,
restore-on-start when a checkpoint exists.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ARCH_IDS, get_config, get_smoke
from ..data import DataConfig, SyntheticLM
from ..launch import steps as steps_mod
from ..optim import adamw
from ..train import checkpoint
from ..train.train_loop import StepWatchdog, TrainConfig, make_train_step


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="smollm-360m")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=5)
    args = ap.parse_args(argv)

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    tcfg = TrainConfig(
        opt=adamw.OptConfig(peak_lr=args.lr, warmup_steps=min(10, args.steps),
                            total_steps=max(args.steps, 1)),
        accum_steps=args.accum, compress_grads=args.compress_grads)
    init_state, train_step = make_train_step(cfg, tcfg)
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                                  global_batch=args.batch, seed=args.seed,
                                  structure=0.9))

    state = init_state(jax.random.PRNGKey(args.seed))
    start_step = 0
    ckpter = None
    if args.ckpt_dir:
        ckpter = checkpoint.AsyncCheckpointer(args.ckpt_dir)
        found, restored = checkpoint.restore_latest(args.ckpt_dir, like=state)
        if found is not None:
            state = jax.tree.map(jnp.asarray, restored)
            start_step = found
            print(f"[restore] resumed from step {found}")

    step_fn = jax.jit(train_step, donate_argnums=(0,))
    watchdog = StepWatchdog()
    losses = []
    for step in range(start_step, args.steps):
        t0 = time.time()
        batch = {k: jnp.asarray(v) for k, v in data.batch(step).items()}
        if cfg.family == "vlm":
            rng = np.random.Generator(np.random.Philox(key=[args.seed, step]))
            batch["prefix_embeds"] = jnp.asarray(
                rng.normal(size=(args.batch, cfg.num_patches, cfg.d_model))
                .astype(np.float32) * 0.02)
        elif cfg.family == "encdec":
            rng = np.random.Generator(np.random.Philox(key=[args.seed, step]))
            batch["prefix_embeds"] = jnp.asarray(
                rng.normal(size=(args.batch, max(args.seq // 4, 8), cfg.d_model))
                .astype(np.float32) * 0.02)
        state, metrics = step_fn(state, batch)
        dt = time.time() - t0
        slow = watchdog.observe(step, dt)
        losses.append(float(metrics["loss"]))
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {losses[-1]:.4f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"gnorm {float(metrics['grad_norm']):.2f} {dt*1e3:.0f}ms"
                  + (" [straggler]" if slow else ""))
        if ckpter and (step + 1) % args.ckpt_every == 0:
            ckpter.submit(step + 1, state)
    if ckpter:
        ckpter.submit(args.steps, state)
        ckpter.close()
    return {"losses": losses, "final_loss": losses[-1] if losses else None,
            "straggler_flags": watchdog.flagged}


if __name__ == "__main__":
    main()
