"""Batched serving engine: slot-based continuous batching over a fixed
decode batch, greedy/temperature sampling, prefill + decode steps that
match the dry-run's ``serve_step`` lowering.

Scale design: the decode batch is a fixed tensor of slots (so the
compiled step never reshapes); finished requests free their slot, the
scheduler packs waiting prompts into free slots and runs a (batched)
prefill for them.  On a real cluster the engine is replicated per model
shard group; here one process drives the whole mesh.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from ..models import get_model


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                    # (t,) int32
    max_new_tokens: int = 32
    temperature: float = 0.0              # 0 = greedy
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg, params, *, max_batch: int = 8, seed: int = 0):
        self.cfg = cfg
        self.api = get_model(cfg)
        self.params = params
        self.max_batch = max_batch
        self.key = jax.random.PRNGKey(seed)
        self._decode = jax.jit(self._decode_step)

    # -------------------------- compiled steps ------------------------------

    def _prefill(self, tokens):
        return self.api.apply(self.params, self.cfg, tokens, mode="prefill")

    def _decode_step(self, params, tokens, caches):
        logits, caches = self.api.apply(params, self.cfg, tokens,
                                        mode="decode", caches=caches)
        return logits[:, -1], caches

    # ---------------------------- scheduling --------------------------------

    def generate(self, requests: list[Request]) -> list[Request]:
        """Run a wave of requests of equal prompt length per wave (padded),
        slot-packed up to max_batch."""
        for wave_start in range(0, len(requests), self.max_batch):
            wave = requests[wave_start: wave_start + self.max_batch]
            self._run_wave(wave)
        return requests

    def _run_wave(self, wave: list[Request]):
        b = len(wave)
        tmax = max(len(r.prompt) for r in wave)
        toks = np.zeros((b, tmax), np.int32)
        for i, r in enumerate(wave):
            toks[i, tmax - len(r.prompt):] = r.prompt  # left-pad
        logits, caches = self._prefill(jnp.asarray(toks))
        last = jnp.argmax(logits[:, -1].astype(jnp.float32), axis=-1)

        steps = max(r.max_new_tokens for r in wave)
        live = np.ones((b,), bool)
        for _ in range(steps):
            for i, r in enumerate(wave):
                if live[i]:
                    r.out_tokens.append(int(last[i]))
                    if len(r.out_tokens) >= r.max_new_tokens:
                        live[i] = False
                        r.done = True
            if not live.any():
                break
            logits, caches = self._decode(self.params, last[:, None].astype(jnp.int32),
                                          caches)
            if any(r.temperature > 0 for r in wave):
                self.key, sub = jax.random.split(self.key)
                temp = jnp.asarray([max(r.temperature, 1e-6) for r in wave])
                sampled = jax.random.categorical(sub, logits / temp[:, None])
                greedy = jnp.argmax(logits, axis=-1)
                last = jnp.where(temp > 1e-5, sampled, greedy)
            else:
                last = jnp.argmax(logits.astype(jnp.float32), axis=-1)
        for r in wave:
            r.done = True


def throughput_stats(n_tokens: int, dt: float) -> dict:
    return {"tokens": n_tokens, "seconds": dt,
            "tokens_per_s": n_tokens / max(dt, 1e-9)}
