"""Serving engine."""
from .engine import Request, ServeEngine
