"""Fault-tolerant checkpointing: atomic writes, content hashes, async
save thread, corrupted-checkpoint fallback, sharding-agnostic restore.

Layout (orbax-like, framework-free):

    <dir>/step_000123/
        manifest.json     {step, leaf paths, shapes, dtypes, sha256, ...}
        arr_<i>.npy       one file per leaf (np.save)
    <dir>/step_000123.COMMITTED     (empty marker written LAST)

A checkpoint without the COMMITTED marker is ignored by restore -- a
crash mid-write can never be loaded.  ``restore_latest`` walks backwards
through steps until a checkpoint passes hash validation, giving automatic
fallback after corruption (tested in tests/test_checkpoint.py).

Multi-host note: in a real N-host deployment each host writes only its
addressable shards under ``host_<k>/`` with the same manifest scheme and
the leader commits; this single-process container writes full arrays --
the commit/validate/fallback logic is identical.
"""

from __future__ import annotations

import hashlib
import json
import os
import queue
import shutil
import threading

import jax
import numpy as np


def _leaf_paths(tree):
    flat, treedef = jax.tree.flatten(tree)
    return flat, treedef


def save(ckpt_dir: str, step: int, tree, *, keep: int = 3) -> str:
    """Atomic synchronous save; returns the checkpoint path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    name = f"step_{step:09d}"
    final = os.path.join(ckpt_dir, name)
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    flat, treedef = _leaf_paths(tree)
    manifest = {"step": step, "treedef": str(treedef), "leaves": []}
    for i, leaf in enumerate(flat):
        arr = np.asarray(leaf)
        fn = f"arr_{i}.npy"
        np.save(os.path.join(tmp, fn), arr)
        with open(os.path.join(tmp, fn), "rb") as f:
            digest = hashlib.sha256(f.read()).hexdigest()
        manifest["leaves"].append(
            {"file": fn, "shape": list(arr.shape), "dtype": str(arr.dtype),
             "sha256": digest})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())

    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    # Commit marker LAST: restore ignores uncommitted checkpoints.
    with open(final + ".COMMITTED", "w") as f:
        f.write(name)
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(list_steps(ckpt_dir))
    for s in steps[:-keep] if keep > 0 else []:
        name = f"step_{s:09d}"
        for p in (os.path.join(ckpt_dir, name),):
            if os.path.isdir(p):
                shutil.rmtree(p)
        marker = os.path.join(ckpt_dir, name + ".COMMITTED")
        if os.path.exists(marker):
            os.remove(marker)


def list_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for fn in os.listdir(ckpt_dir):
        if fn.endswith(".COMMITTED"):
            out.append(int(fn[len("step_"):-len(".COMMITTED")]))
    return sorted(out)


def _validate_and_load(path: str, manifest: dict, like=None):
    leaves = []
    for entry in manifest["leaves"]:
        fp = os.path.join(path, entry["file"])
        with open(fp, "rb") as f:
            if hashlib.sha256(f.read()).hexdigest() != entry["sha256"]:
                raise IOError(f"hash mismatch in {fp}")
        leaves.append(np.load(fp))
    if like is not None:
        flat, treedef = jax.tree.flatten(like)
        if len(flat) != len(leaves):
            raise IOError("checkpoint/state structure mismatch")
        return jax.tree.unflatten(treedef, leaves)
    return leaves


def restore(ckpt_dir: str, step: int, like=None):
    path = os.path.join(ckpt_dir, f"step_{step:09d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    return manifest["step"], _validate_and_load(path, manifest, like)


def restore_latest(ckpt_dir: str, like=None):
    """Walk backwards through committed checkpoints until one validates
    (corruption fallback).  Returns (step, tree) or (None, None)."""
    for step in reversed(list_steps(ckpt_dir)):
        try:
            return restore(ckpt_dir, step, like)
        except (IOError, OSError, ValueError, json.JSONDecodeError):
            continue
    return None, None


class AsyncCheckpointer:
    """Background-thread saver: ``submit`` returns immediately after
    snapshotting device arrays to host; writes happen off the step loop.
    ``wait()`` drains (call before exit)."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._q: queue.Queue = queue.Queue()
        self._err: list[BaseException] = []
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            step, tree = item
            try:
                save(self.ckpt_dir, step, tree, keep=self.keep)
            except BaseException as e:  # surfaced on wait()
                self._err.append(e)
            finally:
                self._q.task_done()

    def submit(self, step: int, tree):
        host_tree = jax.tree.map(np.asarray, tree)  # snapshot before mutation
        self._q.put((step, host_tree))

    def wait(self):
        self._q.join()
        if self._err:
            raise self._err[0]

    def close(self):
        self.wait()
        self._q.put(None)
        self._thread.join(timeout=10)
