"""Elastic scaling: checkpoints are sharding-agnostic pytrees, so a run
can restart on a different mesh (fewer/more pods, smaller data axis) by
re-laying-out the same logical state.

``remesh`` re-device_puts a state pytree under the shardings derived for
the NEW mesh; ``plan_remesh`` reports the reshard traffic (bytes that
change owner) so the launcher can budget restart time.  Failure handling
composes: watchdog flags a straggler / a pod dies -> launcher builds the
survivor mesh -> ``restore_latest`` + ``remesh`` -> training resumes at
the checkpointed step with identical numerics (tests/test_elastic.py).
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def remesh(tree, mesh: Mesh, pspecs):
    """Lay out `tree` on `mesh` with `pspecs` (pytree of PartitionSpecs)."""
    return jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)),
        tree, pspecs, is_leaf=lambda x: isinstance(x, P) or not isinstance(x, (dict, list, tuple)))


def plan_remesh(shapes_tree, old_mesh_shape: dict, new_mesh_shape: dict,
                bytes_per_elem: int = 4) -> dict:
    """Reshard-traffic estimate for a mesh change: every param whose shard
    owner set changes moves once over DCN.  Upper bound: full state size."""
    leaves = jax.tree.leaves(shapes_tree)
    total = sum(int(np.prod(l.shape)) for l in leaves) * bytes_per_elem
    old_n = int(np.prod(list(old_mesh_shape.values())))
    new_n = int(np.prod(list(new_mesh_shape.values())))
    # Fraction that stays put when shrinking/growing along data axis only.
    stay = min(old_n, new_n) / max(old_n, new_n)
    return {
        "state_bytes": total,
        "moved_bytes_upper": int(total * (1 - 0.0)),
        "moved_bytes_typical": int(total * (1 - stay)),
        "old_devices": old_n,
        "new_devices": new_n,
    }


def survivor_mesh(failed_pods: int, pods: int = 2, data: int = 16,
                  model: int = 16, axis_types=None):
    """Build the post-failure mesh: drop whole failed pods (the DCN fault
    domain), keep the in-pod topology intact."""
    import jax as _jax
    live = pods - failed_pods
    if live < 1:
        raise ValueError("no pods left")
    if live == 1:
        return _jax.make_mesh(
            (data, model), ("data", "model"),
            axis_types=axis_types or (_jax.sharding.AxisType.Auto,) * 2)
    return _jax.make_mesh(
        (live, data, model), ("pod", "data", "model"),
        axis_types=axis_types or (_jax.sharding.AxisType.Auto,) * 3)
