"""Training step factory: loss (chunked cross-entropy + MoE aux), gradient
accumulation over microbatches, optional int8 gradient compression with
error feedback for the cross-pod hop, AdamW update.

The returned ``train_step(state, batch)`` is pure and jit/pjit-able; the
launchers attach shardings.  ``state`` = {"params", "opt", "ef"}.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from ..dist import compression
from ..models import get_model
from ..optim import adamw


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: adamw.OptConfig = adamw.OptConfig()
    accum_steps: int = 1              # microbatch gradient accumulation
    loss_chunk: int = 2048            # seq-chunked xent (fp32 never full-size)
    compress_grads: bool = False      # int8 + error feedback (cross-pod DCN)
    aux_loss_weight: float = 0.01


def xent_loss(logits: jax.Array, targets: jax.Array, chunk: int) -> jax.Array:
    """Cross-entropy with seq chunking: fp32 log-softmax is materialized
    only chunk-by-chunk (32k x 152k fp32 logits would not fit otherwise)."""
    b, t, v = logits.shape
    chunk = min(chunk, t)
    pad = (-t) % chunk
    if pad:
        logits = jnp.pad(logits, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)))
    n = (t + pad) // chunk
    lc = logits.reshape(b, n, chunk, v).transpose(1, 0, 2, 3)
    tc = targets.reshape(b, n, chunk).transpose(1, 0, 2)

    def one(args):
        lg, tg = args
        lg = lg.astype(jnp.float32)
        lse = jax.nn.logsumexp(lg, axis=-1)
        # One-hot masked sum, NOT take_along_axis: a gather across the
        # vocab-sharded axis would force XLA to all-gather the fp32 logits
        # (measured: +45 GiB/device on smollm train_4k); the masked sum
        # keeps every tensor vocab-sharded and reduces with a tiny
        # all-reduce instead.
        onehot = jax.lax.broadcasted_iota(
            jnp.int32, lg.shape, lg.ndim - 1) == tg[..., None]
        picked = jnp.sum(jnp.where(onehot, lg, 0.0), axis=-1)
        return lse - picked

    losses = jax.lax.map(one, (lc, tc))  # (n, b, chunk)
    mask = (jnp.arange(t + pad) < t).reshape(n, 1, chunk)
    return (losses * mask).sum() / (b * t)


def make_loss_fn(cfg, api, tcfg: TrainConfig):
    def loss_fn(params, batch):
        prefix = batch.get("prefix_embeds")
        logits, extra = api.apply(params, cfg, batch["tokens"], mode="train",
                                  prefix_embeds=prefix)
        # Prefix positions (VLM) produce logits too; score text positions only.
        t = batch["targets"].shape[1]
        logits = logits[:, -t:]
        loss = xent_loss(logits, batch["targets"], tcfg.loss_chunk)
        aux = jnp.zeros((), jnp.float32)
        if isinstance(extra, dict) and "aux_loss" in extra:
            aux = extra["aux_loss"]
        return loss + tcfg.aux_loss_weight * aux, {"xent": loss, "aux": aux}
    return loss_fn


def make_train_step(cfg, tcfg: TrainConfig):
    """Returns (init_state_fn, train_step_fn)."""
    api = get_model(cfg)
    loss_fn = make_loss_fn(cfg, api, tcfg)

    def init_state(key):
        params = api.init(key, cfg)
        state = {"params": params, "opt": adamw.init(params)}
        if tcfg.compress_grads:
            state["ef"] = compression.init_error_feedback(params)
        return state

    def train_step(state, batch):
        params = state["params"]

        if tcfg.accum_steps > 1:
            def micro(accum, mb):
                (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
                return jax.tree.map(jnp.add, accum,
                                    dict(g=g, l=l, x=m["xent"], a=m["aux"])), None
            zeros = dict(
                g=jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
                l=jnp.zeros((), jnp.float32), x=jnp.zeros((), jnp.float32),
                a=jnp.zeros((), jnp.float32))
            mbs = jax.tree.map(
                lambda x: x.reshape(tcfg.accum_steps,
                                    x.shape[0] // tcfg.accum_steps, *x.shape[1:]),
                batch)
            acc, _ = jax.lax.scan(micro, zeros, mbs)
            k = 1.0 / tcfg.accum_steps
            grads = jax.tree.map(lambda g: g * k, acc["g"])
            loss, metrics = acc["l"] * k, {"xent": acc["x"] * k, "aux": acc["a"] * k}
        else:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)

        new_ef = None
        if tcfg.compress_grads:
            grads, new_ef = compression.compress_decompress_with_ef(
                grads, state["ef"])

        new_params, new_opt, opt_metrics = adamw.update(
            params, grads, state["opt"], tcfg.opt)
        new_state = {"params": new_params, "opt": new_opt}
        if new_ef is not None:
            new_state["ef"] = new_ef
        return new_state, {"loss": loss, **metrics, **opt_metrics}

    return init_state, train_step


# ------------------------- straggler / failure hooks -------------------------


class StepWatchdog:
    """Host-side straggler mitigation hook: tracks step-time EWMA and flags
    outliers (at scale, the launcher reacts by re-sharding around the slow
    host or restoring on a fresh slice -- see train/elastic.py)."""

    def __init__(self, factor: float = 3.0, alpha: float = 0.1):
        self.factor = factor
        self.alpha = alpha
        self.ewma: float | None = None
        self.flagged: list[tuple[int, float]] = []

    def observe(self, step: int, dt: float) -> bool:
        slow = self.ewma is not None and dt > self.factor * self.ewma
        self.ewma = dt if self.ewma is None else \
            (1 - self.alpha) * self.ewma + self.alpha * dt
        if slow:
            self.flagged.append((step, dt))
        return slow
