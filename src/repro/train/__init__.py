"""Training: step factory, checkpointing, elasticity."""
from . import checkpoint, elastic, train_loop
