"""Core of the reproduction: the paper's MKMC->3D-ReRAM mapping.

  kn2row          -- the conv decomposition algorithm (paper §III.B)
  crossbar        -- analog crossbar signal-chain simulator (§II.B, §III.C)
  mapping3d       -- 3D stack mapping + negative-weight separation (§III.C/D)
  costmodel       -- DESTINY-style latency/energy evaluation (§IV)
  crossbar_linear -- PIM-mode linear layers for the LM architectures
"""

from .kn2row import (
    conv1d_causal_kn2row,
    conv1d_depthwise_causal,
    conv1d_depthwise_causal_ref,
    conv2d_direct,
    conv2d_im2col,
    conv2d_kn2row,
)
from .crossbar import CrossbarConfig, crossbar_vmm, crossbar_vmm_tiled, opamp_difference
from .mapping3d import (
    KernelLayerAssignment,
    MappingPlan,
    Stack3DSpec,
    assign_layers,
    mkmc_3d,
    plan_mapping,
)
from .costmodel import (
    ConvLayer,
    Fig9Result,
    HardwareConstants,
    MEMORY_TABLE,
    PAPER_FIG9,
    PAPER_WORKLOADS,
    calibrate,
    cost_2d_reram,
    cost_3d_reram,
    cost_cpu,
    cost_gpu,
    evaluate_fig9,
    normalized_fig8,
)
from .crossbar_linear import CrossbarLinearConfig, crossbar_linear, quantization_error
