"""kn2row-style convolution: the paper's core algorithm (Anderson et al. [9]).

An l1 x l2 convolution of a (c, h, w) image with (n, c, l1, l2) kernels is
decomposed into l1*l2 independent 1x1 convolutions -- each a pure GEMM
[n, c] @ [c, h*w] -- whose partial output maps are *superimposed* (shifted and
accumulated) into the final (n, h, w) output.  In the paper the
superimposition is free in the analog domain (Kirchhoff accumulation across
shared bit lines, eq. 1); on TPU the analogue is accumulating the shifted
partials in fast memory (VMEM scratch in the Pallas kernel, registers here)
so the l1*l2 partial maps are never materialized in HBM.

This module is the pure-jnp reference layer:
  * ``conv2d_kn2row``      -- the paper's algorithm (NCHW, stride 1)
  * ``conv2d_im2col``      -- the "traditional MKMC" baseline the paper
                              argues against (materializes the unrolled
                              [c*l1*l2, h*w] image matrix)
  * ``conv2d_direct``      -- lax.conv_general_dilated oracle
  * ``conv1d_causal_kn2row`` / ``conv1d_depthwise_causal`` -- the 1-D causal
    specialization used inside the xLSTM / RecurrentGemma blocks.

Convention: cross-correlation (as in every DL framework), 'SAME' or 'VALID'
padding, stride 1 (the paper's mapping streams one image column per logical
cycle, i.e. stride 1; strided variants are handled by output subsampling).
"""

from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp
from jax import lax

Padding = Literal["SAME", "VALID"]


def _check_conv_args(image: jax.Array, kernel: jax.Array) -> None:
    if image.ndim != 4:
        raise ValueError(f"image must be (b, c, h, w), got {image.shape}")
    if kernel.ndim != 4:
        raise ValueError(f"kernel must be (n, c, l1, l2), got {kernel.shape}")
    if image.shape[1] != kernel.shape[1]:
        raise ValueError(
            f"channel mismatch: image c={image.shape[1]} kernel c={kernel.shape[1]}"
        )


def conv2d_direct(
    image: jax.Array, kernel: jax.Array, *, padding: Padding = "SAME"
) -> jax.Array:
    """Oracle: XLA's native convolution. image (b,c,h,w), kernel (n,c,l1,l2)."""
    _check_conv_args(image, kernel)
    return lax.conv_general_dilated(
        image,
        kernel,
        window_strides=(1, 1),
        padding=padding,
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )


def conv2d_im2col(
    image: jax.Array, kernel: jax.Array, *, padding: Padding = "SAME"
) -> jax.Array:
    """Traditional MKMC via im2col: unroll kernels into rows of a [n, c*l1*l2]
    matrix and image patches into columns of a [c*l1*l2, oh*ow] matrix.

    This is the baseline the paper rejects for 3D ReRAM: the unrolled image
    matrix is l1*l2 times larger than the image, and the structure cannot use
    the shared-BL accumulation (eq. 1)."""
    _check_conv_args(image, kernel)
    b, c, h, w = image.shape
    n, _, l1, l2 = kernel.shape
    if padding == "SAME":
        ph_lo, ph_hi = (l1 - 1) // 2, l1 // 2
        pw_lo, pw_hi = (l2 - 1) // 2, l2 // 2
        image = jnp.pad(image, ((0, 0), (0, 0), (ph_lo, ph_hi), (pw_lo, pw_hi)))
        oh, ow = h, w
    else:
        oh, ow = h - l1 + 1, w - l2 + 1
    # Extract patches: (b, c*l1*l2, oh*ow).
    patches = lax.conv_general_dilated_patches(
        image,
        filter_shape=(l1, l2),
        window_strides=(1, 1),
        padding="VALID",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )  # (b, c*l1*l2, oh, ow)
    patches = patches.reshape(b, c * l1 * l2, oh * ow)
    kmat = kernel.reshape(n, c * l1 * l2)
    out = jnp.einsum("nk,bkp->bnp", kmat, patches)
    return out.reshape(b, n, oh, ow)


def conv2d_kn2row(
    image: jax.Array, kernel: jax.Array, *, padding: Padding = "SAME"
) -> jax.Array:
    """The paper's algorithm: l1*l2 separate 1x1 GEMMs + shift-accumulate.

    For tap (dy, dx): partial = K[:, :, dy, dx] @ I  (a [n,c] x [c,h*w] GEMM),
    then the partial map is shifted by the tap offset and accumulated.  The
    accumulation is the analog superimposition of paper eq. (1)."""
    _check_conv_args(image, kernel)
    b, c, h, w = image.shape
    n, _, l1, l2 = kernel.shape
    if padding == "SAME":
        oh, ow = h, w
        oy0, ox0 = (l1 - 1) // 2, (l2 - 1) // 2
    else:
        oh, ow = h - l1 + 1, w - l2 + 1
        oy0, ox0 = 0, 0

    acc = jnp.zeros((b, n, oh, ow), dtype=jnp.result_type(image.dtype, kernel.dtype))
    for dy in range(l1):
        for dx in range(l2):
            tap = kernel[:, :, dy, dx]  # (n, c) -- one memristor layer
            partial = jnp.einsum("nc,bchw->bnhw", tap, image)  # 1x1 conv GEMM
            # Superimpose: out[y, x] += partial[y + dy - oy0, x + dx - ox0].
            sy, sx = dy - oy0, dx - ox0
            src_y0, src_x0 = max(sy, 0), max(sx, 0)
            dst_y0, dst_x0 = max(-sy, 0), max(-sx, 0)
            ny = min(h - src_y0, oh - dst_y0)
            nx = min(w - src_x0, ow - dst_x0)
            if ny <= 0 or nx <= 0:
                continue
            acc = acc.at[:, :, dst_y0 : dst_y0 + ny, dst_x0 : dst_x0 + nx].add(
                partial[:, :, src_y0 : src_y0 + ny, src_x0 : src_x0 + nx]
            )
    return acc


# ---------------------------------------------------------------------------
# 1-D causal specialization (used by xLSTM / RecurrentGemma blocks).
# ---------------------------------------------------------------------------


def conv1d_depthwise_causal(x: jax.Array, weight: jax.Array) -> jax.Array:
    """Depthwise causal conv1d via the kn2row decomposition.

    x: (b, t, c); weight: (l, c).  out[t, c] = sum_i w[i, c] * x[t - l + 1 + i, c]
    -- i.e. tap i of the kernel is a diagonal 1x1 'GEMM' (elementwise scale),
    shifted in time and accumulated.  This is the exact 1-D analogue of the
    paper's mapping: each tap occupies one memristor layer and the shared-BL
    accumulation sums the shifted partials."""
    if x.ndim != 3 or weight.ndim != 2 or x.shape[-1] != weight.shape[-1]:
        raise ValueError(f"bad shapes x={x.shape} w={weight.shape}")
    l = weight.shape[0]
    t = x.shape[1]
    acc = jnp.zeros_like(x, dtype=jnp.result_type(x.dtype, weight.dtype))
    for i in range(l):
        shift = l - 1 - i  # tap i reads x[t - shift]
        if shift == 0:
            acc = acc + x * weight[i]
        elif shift < t:
            acc = acc.at[:, shift:, :].add(x[:, : t - shift, :] * weight[i])
    return acc.astype(x.dtype)


def conv1d_causal_kn2row(x: jax.Array, kernel: jax.Array) -> jax.Array:
    """Dense causal conv1d via kn2row: kernel (l, c_in, c_out); x (b, t, c_in).

    out[t, :] = sum_i x[t - l + 1 + i, :] @ kernel[i]  -- l shifted GEMMs."""
    if x.ndim != 3 or kernel.ndim != 3 or x.shape[-1] != kernel.shape[1]:
        raise ValueError(f"bad shapes x={x.shape} k={kernel.shape}")
    l, _, c_out = kernel.shape
    b, t, _ = x.shape
    acc = jnp.zeros((b, t, c_out), dtype=jnp.result_type(x.dtype, kernel.dtype))
    for i in range(l):
        partial = jnp.einsum("btc,cd->btd", x, kernel[i])
        shift = l - 1 - i
        if shift == 0:
            acc = acc + partial
        elif shift < t:
            acc = acc.at[:, shift:, :].add(partial[:, : t - shift, :])
    return acc.astype(x.dtype)


def conv1d_depthwise_causal_ref(x: jax.Array, weight: jax.Array) -> jax.Array:
    """Oracle for the depthwise causal conv via explicit padding + windows."""
    l, c = weight.shape
    xp = jnp.pad(x, ((0, 0), (l - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.result_type(x.dtype, weight.dtype))
    for i in range(l):
        out = out + xp[:, i : i + x.shape[1], :] * weight[i]
    return out.astype(x.dtype)


@functools.partial(jax.jit, static_argnames=("padding",))
def conv2d_kn2row_jit(image, kernel, *, padding: Padding = "SAME"):
    return conv2d_kn2row(image, kernel, padding=padding)
