"""Mapping of MKMC convolution onto a monolithic 3D ReRAM stack (paper §III.C).

Accounting rules implemented exactly as the paper specifies:

  * The stack has L memristor layers; shared WLs/BLs force an EVEN number of
    layers per configuration.  A kernel with l x l taps needs l^2 layers;
    if l^2 is odd, one extra DUMMY layer is provisioned (either programmed to
    ~zero conductance or its WL driven to 0 V).
  * Voltage planes = layers/2 + 1; current planes = layers/2 (horizontally
    integrated stack, Fig. 1).
  * Each voltage plane carries c word lines (one image-matrix column per
    logical cycle); each current plane carries n bit lines (one per kernel).
  * If l^2 exceeds the stack depth, the computation is repeated in
    ceil(l^2 / L) passes (the paper: 16 layers handle 3x3 in one pass, 5x5
    needs two).
  * Negative-weight separation (paper Fig. 6): per kernel, tap planes are
    reordered so negative weights occupy layers below a per-kernel
    *separation voltage plane* and non-negative weights occupy layers above;
    the two groups accumulate on disjoint current-plane sets (I_n, I_p) and
    an op-amp reads I_p - I_n.

Generalization note (documented in DESIGN.md): the paper's example uses taps
whose c channel values share one sign.  For mixed-sign taps we split the tap
into its negative and non-negative parts, each occupying a layer in its
group; purely-one-sign taps occupy a single layer (this preserves the
paper's 1x-cell advantage whenever taps are sign-pure, and degrades
gracefully -- never worse than the differential baseline's 2x).
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

from . import crossbar as xbar
from . import kn2row


@dataclasses.dataclass(frozen=True)
class Stack3DSpec:
    """Hardware shape of one monolithic 3D ReRAM crossbar stack."""

    layers: int = 16          # memristor layers (paper's choice: 16)
    wl_per_plane: int = 128   # word lines per voltage plane (channel capacity)
    bl_per_plane: int = 128   # bit lines per current plane (kernel capacity)

    def __post_init__(self):
        if self.layers % 2 != 0:
            raise ValueError("shared WL/BL structure requires an even layer count")

    @property
    def voltage_planes(self) -> int:
        return self.layers // 2 + 1

    @property
    def current_planes(self) -> int:
        return self.layers // 2


@dataclasses.dataclass(frozen=True)
class MappingPlan:
    """Static plan for one MKMC layer on one stack spec (feeds the cost model)."""

    n: int                    # kernels
    c: int                    # channels
    l1: int
    l2: int
    h: int
    w: int
    taps: int                 # l1*l2
    layers_used: int          # taps rounded up to even
    dummy_layers: int         # 0 or 1
    voltage_planes: int
    current_planes: int
    passes: int               # ceil(layers_used / stack.layers)
    tiles_c: int              # ceil(c / wl_per_plane)
    tiles_n: int              # ceil(n / bl_per_plane)
    logical_cycles: int       # h*w per pass (one image column per cycle)
    total_cycles: int         # passes * tiles_c * tiles_n * h * w
    memristors_used: int      # layers_used * c * n  (separated scheme, 1x)
    memristors_differential: int  # 2x cells for the differential baseline
    adc_conversions: int      # separated: 2 groups/BL/cycle; see cost model
    dac_drives: int

    @property
    def utilization(self) -> float:
        cap = self.passes * self.tiles_c * self.tiles_n
        cap *= self.layers_used * self.c * self.n
        return self.memristors_used / cap if cap else 0.0


def plan_mapping(
    n: int, c: int, l1: int, l2: int, h: int, w: int, spec: Stack3DSpec = Stack3DSpec()
) -> MappingPlan:
    taps = l1 * l2
    layers_used = taps + (taps % 2)          # dummy layer when odd
    dummy = layers_used - taps
    passes = max(1, math.ceil(layers_used / spec.layers))
    tiles_c = max(1, math.ceil(c / spec.wl_per_plane))
    tiles_n = max(1, math.ceil(n / spec.bl_per_plane))
    cycles = h * w
    total = passes * tiles_c * tiles_n * cycles
    # Per cycle: every WL in use is driven once (shared WLs serve the layer
    # above and below -> one DAC per WL, not per layer); every BL is read
    # twice in the separated scheme (I_p group and I_n group op-amp output is
    # a single ADC conversion -- the subtraction is analog, so ONE conversion
    # per BL per cycle).
    adc = total * min(n, spec.bl_per_plane if tiles_n > 1 else n)
    dac = total * min(c, spec.wl_per_plane if tiles_c > 1 else c) * (
        min(layers_used, spec.layers) // 2 + 1
    )
    return MappingPlan(
        n=n, c=c, l1=l1, l2=l2, h=h, w=w,
        taps=taps,
        layers_used=layers_used,
        dummy_layers=dummy,
        voltage_planes=min(layers_used, spec.layers) // 2 + 1,
        current_planes=min(layers_used, spec.layers) // 2,
        passes=passes,
        tiles_c=tiles_c,
        tiles_n=tiles_n,
        logical_cycles=cycles,
        total_cycles=total,
        memristors_used=layers_used * c * n,
        memristors_differential=2 * taps * c * n,
        adc_conversions=adc,
        dac_drives=dac,
    )


# ---------------------------------------------------------------------------
# Negative-weight layer assignment (paper Fig. 6 flow).
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class KernelLayerAssignment:
    """Per-kernel layer placement produced by the Fig. 6 flow."""

    kernel_index: int
    neg_tap_ids: tuple[int, ...]      # taps whose (split) negative part is mapped low
    pos_tap_ids: tuple[int, ...]      # taps whose (split) non-negative part is mapped high
    mixed_tap_ids: tuple[int, ...]    # taps present in both groups (split)
    separation_plane: int             # voltage-plane index separating the groups
    layers_needed: int                # |neg| + |pos| (after splitting), rounded even

    @property
    def n_neg_layers(self) -> int:
        return len(self.neg_tap_ids)

    @property
    def n_pos_layers(self) -> int:
        return len(self.pos_tap_ids)


def assign_layers(kernel: np.ndarray | jax.Array, *, tol: float = 0.0) -> list[KernelLayerAssignment]:
    """Scan each of the n kernels (paper Fig. 6 step 1): classify each of the
    l1*l2 tap planes (a c-vector) as negative / non-negative / mixed, place
    negative parts below the separation plane and non-negative above.

    Returns one assignment per kernel.  Layer indices are abstract (0 =
    bottom); the separation plane index counts voltage planes from the
    bottom, matching the paper's worked example (§III.D)."""
    k = np.asarray(kernel)
    if k.ndim != 4:
        raise ValueError(f"kernel must be (n, c, l1, l2), got {k.shape}")
    n, c, l1, l2 = k.shape
    out: list[KernelLayerAssignment] = []
    for j in range(n):
        taps = k[j].reshape(c, l1 * l2).T  # (taps, c)
        neg, pos, mixed = [], [], []
        for t_id, tap in enumerate(taps):
            has_neg = bool((tap < -tol).any())
            has_pos = bool((tap > tol).any())
            if has_neg and has_pos:
                mixed.append(t_id)
                neg.append(t_id)
                pos.append(t_id)
            elif has_neg:
                neg.append(t_id)
            else:
                # all-zero taps count as non-negative (paper maps zeros high
                # or uses dummy-layer handling; either is correct)
                pos.append(t_id)
        layers = len(neg) + len(pos)
        layers += layers % 2
        # Separation plane: the voltage plane just above the negative block.
        # With |neg| layers below it, the plane index equals ceil(|neg|/2)
        # in the shared-plane indexing of the worked example: kernel 0 there
        # has 4 negative layers -> separation plane 2; kernel 1 has 1 -> 1.
        sep = math.ceil(len(neg) / 2)
        out.append(
            KernelLayerAssignment(
                kernel_index=j,
                neg_tap_ids=tuple(neg),
                pos_tap_ids=tuple(pos),
                mixed_tap_ids=tuple(mixed),
                separation_plane=sep,
                layers_needed=layers,
            )
        )
    return out


# ---------------------------------------------------------------------------
# Functional 3D-stack MKMC simulation (digital-exact data path + analog
# quantization via the crossbar simulator).
# ---------------------------------------------------------------------------


def mkmc_3d(
    image: jax.Array,
    kernel: jax.Array,
    spec: Stack3DSpec = Stack3DSpec(),
    cfg: xbar.CrossbarConfig = xbar.CrossbarConfig(),
) -> jax.Array:
    """MKMC through the simulated 3D stack.

    The superimposition across taps happens *pre-ADC* (analog accumulation on
    shared BLs across current planes, eq. 1): for each spatial output we sum
    the shifted tap partials of the I_p group and the I_n group in analog,
    subtract (op-amp), and convert once.  Tiling over (c, n) follows the
    plan; each c-tile contributes a separately-converted partial (digital
    accumulation across c tiles, as in any multi-crossbar design)."""
    b, c, h, w = image.shape
    n, _, l1, l2 = kernel.shape
    if cfg.scheme == "ideal":
        return kn2row.conv2d_kn2row(image, kernel)

    w_scale = jnp.maximum(jnp.abs(kernel).max(), 1e-30)
    x_scale = jnp.maximum(jnp.abs(image).max(), 1e-30)
    # DAC: one WL drive per channel per logical cycle (shared across planes).
    v = xbar._quantize_signed(image / x_scale, cfg.dac_bits, jnp.asarray(1.0))

    out = jnp.zeros((b, n, h, w), dtype=jnp.float32)
    tile_c = spec.wl_per_plane
    for c0 in range(0, c, tile_c):
        c1 = min(c0 + tile_c, c)
        i_p = jnp.zeros((b, n, h, w), dtype=jnp.float32)
        i_n = jnp.zeros((b, n, h, w), dtype=jnp.float32)
        for dy in range(l1):
            for dx in range(l2):
                tap = kernel[:, c0:c1, dy, dx] / w_scale  # (n, ct), in [-1, 1]
                # Conductances are globally normalized (one weight scale for
                # the whole stack -- all planes share the output post-scale).
                g_pos = xbar._quantize_unsigned(
                    jnp.maximum(tap.T, 0.0), cfg.weight_bits, jnp.asarray(1.0))
                g_neg = xbar._quantize_unsigned(
                    jnp.maximum(-tap.T, 0.0), cfg.weight_bits, jnp.asarray(1.0))
                part_p = jnp.einsum("km,bkhw->bmhw", g_pos, v[:, c0:c1])
                part_n = jnp.einsum("km,bkhw->bmhw", g_neg, v[:, c0:c1])
                sy, sx = dy - (l1 - 1) // 2, dx - (l2 - 1) // 2
                src_y0, src_x0 = max(sy, 0), max(sx, 0)
                dst_y0, dst_x0 = max(-sy, 0), max(-sx, 0)
                ny = min(h - src_y0, h - dst_y0)
                nx = min(w - src_x0, w - dst_x0)
                if ny <= 0 or nx <= 0:
                    continue
                sl_dst = (slice(None), slice(None), slice(dst_y0, dst_y0 + ny), slice(dst_x0, dst_x0 + nx))
                sl_src = (slice(None), slice(None), slice(src_y0, src_y0 + ny), slice(src_x0, src_x0 + nx))
                i_p = i_p.at[sl_dst].add(part_p[sl_src])
                i_n = i_n.at[sl_dst].add(part_n[sl_src])
        # Op-amp difference then ONE ADC conversion per BL per cycle.
        i_diff = xbar.opamp_difference(i_p, i_n)
        i_range = jnp.asarray(float(min(tile_c, c1 - c0) * l1 * l2), dtype=jnp.float32)
        q = xbar.adc_quantize(i_diff, cfg, i_range)
        # Digital accumulation across c tiles (multi-crossbar partials);
        # n tiling replicates the image drive and is numerically identical.
        out = out + q
    return out * (w_scale * x_scale)
