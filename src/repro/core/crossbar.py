"""Numerical simulator for analog ReRAM crossbar vector-matrix multiplication.

Models the signal chain of the paper's crossbars (Fig. 3 / Fig. 7):

  digital input --DAC--> WL voltages --Ohm's law--> per-cell currents
  --Kirchhoff (shared BLs, eq. 1)--> accumulated BL currents
  --op-amp I_p - I_n (paper's negative-weight separation)--> signed current
  --ADC--> digital output

Two signed-weight schemes are modelled:

  * ``differential``  -- the conventional baseline: every weight uses TWO
    memristors (G+ holds max(w,0), G- holds max(-w,0)); doubles cell count
    and the two columns are subtracted after the array.
  * ``separated``     -- the paper's contribution: weights are PARTITIONED
    into a negative group and a non-negative group (per kernel / per output
    column), mapped to disjoint layer/plane sets, accumulated separately in
    analog (I_n, I_p) and subtracted by one inverting op-amp (Fig. 7e).
    Cell count stays 1x; only the group sums need the subtractor.

Both schemes are numerically exact in infinite precision; they differ in
*which* quantization noise they see (the separated scheme quantizes I_p and
I_n with the same ADC range but half the conversions of a per-tap digital
accumulation) and in the cost model (cells, ADC conversions, op-amps).
"""

from __future__ import annotations

import dataclasses
from typing import Literal

import jax
import jax.numpy as jnp

Scheme = Literal["differential", "separated", "ideal"]


@dataclasses.dataclass(frozen=True)
class CrossbarConfig:
    """Quantization / signal-chain parameters for the simulator.

    The defaults follow the paper's setup: multi-bit ReRAM cells and
    DAC/ADC resolutions in the range used by ISAAC-class designs (the paper
    cites Murmann's ADC survey for converter figures).
    """

    weight_bits: int = 8        # conductance levels per memristor = 2^bits - 1
    dac_bits: int = 8           # input voltage levels
    adc_bits: int = 10          # output current levels
    scheme: Scheme = "separated"
    g_on_off_ratio: float = 1e3  # R_off / R_on; bounds the min conductance
    read_noise_sigma: float = 0.0  # relative lognormal-ish read noise (off by default)

    def __post_init__(self):
        if self.weight_bits < 1 or self.dac_bits < 1 or self.adc_bits < 1:
            raise ValueError("bit widths must be >= 1")


def _quantize_unsigned(x: jax.Array, bits: int, scale: jax.Array) -> jax.Array:
    """Uniform quantization of non-negative x onto [0, scale] with 2^bits - 1 steps."""
    levels = (1 << bits) - 1
    safe = jnp.maximum(scale, 1e-30)
    q = jnp.round(jnp.clip(x / safe, 0.0, 1.0) * levels) / levels
    return q * safe


def _quantize_signed(x: jax.Array, bits: int, scale: jax.Array) -> jax.Array:
    """Uniform symmetric quantization of x onto [-scale, scale]."""
    levels = (1 << bits) - 1
    safe = jnp.maximum(scale, 1e-30)
    q = jnp.round(jnp.clip(x / safe, -1.0, 1.0) * levels) / levels
    return q * safe


def program_conductances(
    w: jax.Array, cfg: CrossbarConfig
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Map a signed weight matrix (rows=WL inputs, cols=BL outputs) to
    non-negative conductance matrices (G_pos, G_neg) plus the weight scale.

    Conductances are normalized to [0, 1] (units of g_max); the digital
    post-scale restores magnitudes.  The finite on/off ratio makes exact
    zero unreachable: g_min = 1 / on_off_ratio."""
    w_scale = jnp.maximum(jnp.max(jnp.abs(w)), 1e-30)
    g_min = 1.0 / cfg.g_on_off_ratio
    pos = _quantize_unsigned(jnp.maximum(w, 0.0) / w_scale, cfg.weight_bits, jnp.asarray(1.0))
    neg = _quantize_unsigned(jnp.maximum(-w, 0.0) / w_scale, cfg.weight_bits, jnp.asarray(1.0))
    # Cells that should be "off" still leak g_min: model as clamping from below.
    g_pos = jnp.where(pos > 0, jnp.maximum(pos, g_min), g_min)
    g_neg = jnp.where(neg > 0, jnp.maximum(neg, g_min), g_min)
    return g_pos, g_neg, w_scale


def dac_quantize(x: jax.Array, cfg: CrossbarConfig) -> tuple[jax.Array, jax.Array]:
    """Digital inputs -> WL voltage levels (signed handled by bipolar drive)."""
    x_scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-30)
    v = _quantize_signed(x / x_scale, cfg.dac_bits, jnp.asarray(1.0))
    return v, x_scale


def adc_quantize(i: jax.Array, cfg: CrossbarConfig, i_range: jax.Array) -> jax.Array:
    """BL currents -> digital codes.  Range is the analog full-scale of the
    column (worst-case sum), shared across the batch as real ADCs are."""
    return _quantize_signed(i, cfg.adc_bits, i_range)


def crossbar_vmm(
    x: jax.Array,
    w: jax.Array,
    cfg: CrossbarConfig = CrossbarConfig(),
    *,
    key: jax.Array | None = None,
) -> jax.Array:
    """Simulated analog VMM:  out = x @ w  through the crossbar signal chain.

    x: (..., k) digital inputs; w: (k, m) signed weights.
    Returns (..., m) digital outputs after DAC/conductance/ADC quantization.
    """
    if w.ndim != 2 or x.shape[-1] != w.shape[0]:
        raise ValueError(f"bad shapes x={x.shape} w={w.shape}")
    if cfg.scheme == "ideal":
        return x @ w

    g_pos, g_neg, w_scale = program_conductances(w, cfg)
    v, x_scale = dac_quantize(x, cfg)

    if cfg.read_noise_sigma > 0.0:
        if key is None:
            raise ValueError("read_noise_sigma > 0 requires a PRNG key")
        kp, kn = jax.random.split(key)
        g_pos = g_pos * (1.0 + cfg.read_noise_sigma * jax.random.normal(kp, g_pos.shape))
        g_neg = g_neg * (1.0 + cfg.read_noise_sigma * jax.random.normal(kn, g_neg.shape))

    # Ohm + Kirchhoff: column currents for each group.  In the 'separated'
    # scheme the groups live on disjoint current-plane sets of ONE array
    # (cells = k*m); in 'differential' each weight owns two cells (2*k*m).
    i_p = v @ g_pos
    i_n = v @ g_neg
    # Op-amp subtraction (Fig. 7e): I2 = I_p - I_n, still analog.
    i_diff = i_p - i_n
    # ADC full-scale: worst-case column current (per-column calibration).
    i_range = jnp.maximum(
        jnp.sum(g_pos, axis=0).max(), jnp.sum(g_neg, axis=0).max()
    ) * jnp.asarray(1.0)
    out = adc_quantize(i_diff, cfg, i_range)
    return out * (w_scale * x_scale)


def crossbar_vmm_tiled(
    x: jax.Array,
    w: jax.Array,
    cfg: CrossbarConfig = CrossbarConfig(),
    *,
    tile_k: int = 128,
    tile_m: int = 128,
) -> jax.Array:
    """VMM through an array of finite (tile_k x tile_m) crossbars.

    Real arrays are bounded (the paper's planes hold c WLs x n BLs); larger
    operands tile across crossbars with digital accumulation over the k tiles
    (each k-tile's partial goes through its own ADC -- this is what the cost
    model charges)."""
    k, m = w.shape
    out = jnp.zeros((*x.shape[:-1], m), dtype=jnp.result_type(x.dtype, w.dtype))
    for k0 in range(0, k, tile_k):
        k1 = min(k0 + tile_k, k)
        for m0 in range(0, m, tile_m):
            m1 = min(m0 + tile_m, m)
            part = crossbar_vmm(x[..., k0:k1], w[k0:k1, m0:m1], cfg)
            out = out.at[..., m0:m1].add(part)
    return out


def opamp_difference(i_p: jax.Array, i_n: jax.Array) -> jax.Array:
    """The inverting op-amp of Fig. 7(e), proved in the paper:
    I0 = I_n, V0 = I_n*R0, V1 = -I_n*R0, I1 = -I_n, I2 = I_p - I_n."""
    return i_p - i_n
