"""Analytic latency/energy model reproducing the paper's evaluation (§IV).

The container has neither ReRAM nor the paper's CPU/GPU, so the paper's
evaluation is reproduced the way the paper itself produced it: from
device-level constants (DESTINY Table I, CACTI interconnects, Murmann ADC
survey) plus a structural model of the mapping (cycles / array accesses /
DAC-ADC conversions from ``mapping3d.plan_mapping``).

Model structure (free parameters marked [cal]):

  3D ReRAM   t = total_cycles * t_read * fig8_lat(L)
             E = cells_energy * fig8_en(L) + DACs*e_dac + ADCs*e_adc
  2D ReRAM   same memristor count, planar: no shared WL/BL, so the l^2 tap
             partials are converted and summed digitally -> L x the
             conversions, taps serialized over the shared peripherals
             (t = cycles * L * t_read), as the paper's custom baseline.
  CPU / GPU  t = FLOPs / (peak * eta) [cal], E = t * P_avg.

Calibration: four monotone knobs (fig8_lat(16) [Fig 8 is a plot, values not
in the text], the ADC energy within Murmann-survey range, eta_cpu, eta_gpu
["measured within the framework" -- not given numerically]) are solved so the
model reproduces the paper's four primary ratios (5.79x, 2.12x, 927.81x,
36.8x) on the paper's workload; the remaining two reported ratios
(1802.64x, 114.1x energy vs CPU/GPU) are *predictions* used as a
cross-check.  See benchmarks/bench_fig9.py for residuals.
"""

from __future__ import annotations

import dataclasses
import math

from .mapping3d import MappingPlan, Stack3DSpec, plan_mapping

# ---------------------------------------------------------------------------
# Paper Table I: DESTINY, 1 GB @ 32 nm.
# ---------------------------------------------------------------------------

MEMORY_TABLE = {
    #            write_nJ, read_nJ, write_ns, read_ns
    "ReRAM":    (1.907, 1.623, 15.274, 13.948),
    "eDRAM":    (3.407, 3.324, 34.207, 66.661),
    "SRAM":     (6.687, 6.688, 144.556, 279.546),
    "STT-RAM":  (2.102, 1.975, 13.469, 18.06),
}


@dataclasses.dataclass(frozen=True)
class ConvLayer:
    """One MKMC workload (inference, no batching -- as the paper evaluates)."""

    name: str
    n: int      # kernels
    c: int      # channels
    h: int
    w: int
    l: int      # kernel side

    @property
    def flops(self) -> float:
        # MACs*2, SAME padding, stride 1 (the paper's mapping).
        return 2.0 * self.n * self.c * self.l * self.l * self.h * self.w


# The paper benchmarks "several selected MKMC layers" from VGG-16, GoogLeNet
# and AlexNet (ImageNet inference, single image).  Representative selection:
PAPER_WORKLOADS: tuple[ConvLayer, ...] = (
    # VGG-16 [14]
    ConvLayer("vgg16_conv1_2", n=64, c=64, h=224, w=224, l=3),
    ConvLayer("vgg16_conv2_2", n=128, c=128, h=112, w=112, l=3),
    ConvLayer("vgg16_conv3_3", n=256, c=256, h=56, w=56, l=3),
    ConvLayer("vgg16_conv4_3", n=512, c=512, h=28, w=28, l=3),
    ConvLayer("vgg16_conv5_3", n=512, c=512, h=14, w=14, l=3),
    # AlexNet [16]
    ConvLayer("alexnet_conv2", n=256, c=96, h=27, w=27, l=5),
    ConvLayer("alexnet_conv3", n=384, c=256, h=13, w=13, l=3),
    ConvLayer("alexnet_conv5", n=256, c=384, h=13, w=13, l=3),
    # GoogLeNet [15] (inception 3x3 / 5x5 branches)
    ConvLayer("googlenet_inc3a_3x3", n=128, c=96, h=28, w=28, l=3),
    ConvLayer("googlenet_inc4e_3x3", n=320, c=160, h=14, w=14, l=3),
    ConvLayer("googlenet_inc3a_5x5", n=32, c=16, h=28, w=28, l=5),
)


@dataclasses.dataclass(frozen=True)
class HardwareConstants:
    """Device constants; [cal] marks calibrated values (see module docstring)."""

    # ReRAM array (Table I scaled to one crossbar access; DESTINY reports a
    # 1 GB bank -- per-crossbar access cost scales with the active slice).
    t_read_ns: float = 13.948            # Table I
    e_read_nJ: float = 1.623             # Table I, per active-array access
    # Fig. 8 factors (normalized to 2-layer); anchored f(2)=1, calibrated at 16.
    fig8_lat_16: float = 1.7739          # [cal] -> reproduces 5.79x vs 2D
    fig8_en_16: float = 1.45             # Fig 8 trend: energy grows ~1.5x @16L
    # Converters (B. Murmann, ADC Performance Survey [13]).
    e_dac_pJ: float = 1.9                # 8-bit DAC drive
    e_adc_pJ: float = 2.300              # [cal] 10-bit SAR ADC, survey range 2..30 pJ
    # Whole-tile energy multiplier: the paper's energy includes the tile
    # periphery of Fig. 4 (eDRAM buffer traffic, shared bus, controller,
    # CACTI-modelled interconnect), not just the crossbar slice.  [cal]
    # against the paper's CPU energy ratio; applies equally to the 2D
    # baseline (same tile architecture), so the 2D/3D ratio is unaffected.
    system_energy_scale: float = 273.41  # [cal]
    # CPU: Intel i7-5700HQ -- 4 cores, 2.7 GHz, AVX2 FMA: 4*2.7e9*16 = 172.8 GF/s.
    cpu_peak_gflops: float = 172.8
    cpu_eta: float = 0.04461             # [cal] TF measured efficiency
    cpu_power_w: float = 47.0            # TDP (Intel ARK [17])
    # GPU: GTX 1080 Ti -- 11.34 TFLOP/s fp32, 250 W board power.
    gpu_peak_gflops: float = 11340.0
    gpu_eta: float = 0.01714             # [cal] TF measured efficiency (kn2row, bs=1)
    gpu_power_w: float = 250.0
    gpu_util: float = 0.6                # nvidia-smi average draw fraction


DEFAULT_HW = HardwareConstants()


def fig8_latency_factor(layers: int, hw: HardwareConstants = DEFAULT_HW) -> float:
    """Normalized read latency vs layer count (paper Fig. 8): monotone
    increase from 1.0 at 2 layers, linear in the layer count (the figure
    shows a near-linear trend)."""
    if layers < 2:
        raise ValueError("3D stack has >= 2 layers")
    return 1.0 + (hw.fig8_lat_16 - 1.0) * (layers - 2) / 14.0


def fig8_energy_factor(layers: int, hw: HardwareConstants = DEFAULT_HW) -> float:
    if layers < 2:
        raise ValueError("3D stack has >= 2 layers")
    return 1.0 + (hw.fig8_en_16 - 1.0) * (layers - 2) / 14.0


@dataclasses.dataclass(frozen=True)
class CostBreakdown:
    device: str
    time_s: float
    energy_j: float
    detail: dict


def _array_access_energy(plan: MappingPlan, spec: Stack3DSpec, hw: HardwareConstants) -> float:
    """Energy of one full-stack access, scaled from Table I by the active
    slice of the crossbar (c WLs x n BLs x L layers vs the full array)."""
    active_cells = min(plan.c, spec.wl_per_plane) * min(plan.n, spec.bl_per_plane)
    active_cells *= min(plan.layers_used, spec.layers)
    full_cells = spec.wl_per_plane * spec.bl_per_plane * spec.layers
    return hw.e_read_nJ * 1e-9 * (active_cells / full_cells)


def cost_3d_reram(
    layer: ConvLayer, spec: Stack3DSpec = Stack3DSpec(), hw: HardwareConstants = DEFAULT_HW
) -> CostBreakdown:
    plan = plan_mapping(layer.n, layer.c, layer.l, layer.l, layer.h, layer.w, spec)
    lat_f = fig8_latency_factor(spec.layers, hw)
    en_f = fig8_energy_factor(spec.layers, hw)
    t = plan.total_cycles * hw.t_read_ns * lat_f * 1e-9
    # Per cycle: shared WLs -> one DAC drive per WL per voltage plane pair
    # ("roughly half" the drives of unshared planes); analog superimposition
    # -> ONE ADC conversion per BL (op-amp output), not one per tap.
    c_eff = min(plan.c, spec.wl_per_plane)
    n_eff = min(plan.n, spec.bl_per_plane)
    layers_eff = min(plan.layers_used, spec.layers)
    dacs_per_cycle = c_eff * (layers_eff // 2 + 1)   # shared-WL planes
    adcs_per_cycle = n_eff                           # post-op-amp, per BL
    e_cycle = (
        _array_access_energy(plan, spec, hw) * en_f
        + dacs_per_cycle * hw.e_dac_pJ * 1e-12
        + adcs_per_cycle * hw.e_adc_pJ * 1e-12
    )
    e = plan.total_cycles * e_cycle * hw.system_energy_scale
    return CostBreakdown(
        "3D-ReRAM", t, e,
        dict(cycles=plan.total_cycles, lat_factor=lat_f,
             dacs_per_cycle=dacs_per_cycle, adcs_per_cycle=adcs_per_cycle,
             plan=plan),
    )


def cost_2d_reram(
    layer: ConvLayer, spec: Stack3DSpec = Stack3DSpec(), hw: HardwareConstants = DEFAULT_HW
) -> CostBreakdown:
    """The paper's custom 2D baseline: SAME memristor count, planar arrays.

    No shared WL/BL: every tap plane is a separate planar crossbar with its
    own peripheral activity; the tap partials are converted separately and
    accumulated digitally.  Shared peripheral banks serialize the taps
    (ISAAC-style ADC sharing), so per output column the 2D design spends
    layers_eff array cycles at 2-layer-equivalent latency."""
    plan = plan_mapping(layer.n, layer.c, layer.l, layer.l, layer.h, layer.w, spec)
    layers_eff = min(plan.layers_used, spec.layers)
    cycles = plan.total_cycles * layers_eff
    t = cycles * hw.t_read_ns * 1e-9
    c_eff = min(plan.c, spec.wl_per_plane)
    n_eff = min(plan.n, spec.bl_per_plane)
    # Per tap-cycle: c DAC drives (no shared WLs) and n ADC conversions
    # (every tap partial converted -> L x the conversions of the 3D stack).
    e_cycle = (
        _array_access_energy(plan, spec, hw) / max(layers_eff, 1)
        + c_eff * hw.e_dac_pJ * 1e-12
        + n_eff * hw.e_adc_pJ * 1e-12
    )
    e = cycles * e_cycle * hw.system_energy_scale
    return CostBreakdown(
        "2D-ReRAM", t, e, dict(cycles=cycles, taps_serialized=layers_eff, plan=plan)
    )


def cost_cpu(layer: ConvLayer, hw: HardwareConstants = DEFAULT_HW) -> CostBreakdown:
    t = layer.flops / (hw.cpu_peak_gflops * 1e9 * hw.cpu_eta)
    return CostBreakdown("CPU", t, t * hw.cpu_power_w, dict(flops=layer.flops))


def cost_gpu(layer: ConvLayer, hw: HardwareConstants = DEFAULT_HW) -> CostBreakdown:
    t = layer.flops / (hw.gpu_peak_gflops * 1e9 * hw.gpu_eta)
    return CostBreakdown("GPU", t, t * hw.gpu_power_w * hw.gpu_util, dict(flops=layer.flops))


@dataclasses.dataclass(frozen=True)
class Fig9Result:
    speedup_vs_2d: float
    speedup_vs_cpu: float
    speedup_vs_gpu: float
    energy_saving_vs_2d: float
    energy_saving_vs_cpu: float
    energy_saving_vs_gpu: float


PAPER_FIG9 = Fig9Result(5.79, 927.81, 36.8, 2.12, 1802.64, 114.1)


def evaluate_fig9(
    workloads: tuple[ConvLayer, ...] = PAPER_WORKLOADS,
    spec: Stack3DSpec = Stack3DSpec(),
    hw: HardwareConstants = DEFAULT_HW,
) -> Fig9Result:
    """Aggregate ratios over the workload set (total time / total energy,
    i.e. the workload-weighted mean the paper reports)."""
    t3 = e3 = t2 = e2 = tc = ec = tg = eg = 0.0
    for wl in workloads:
        r3, r2 = cost_3d_reram(wl, spec, hw), cost_2d_reram(wl, spec, hw)
        rc, rg = cost_cpu(wl, hw), cost_gpu(wl, hw)
        t3 += r3.time_s; e3 += r3.energy_j
        t2 += r2.time_s; e2 += r2.energy_j
        tc += rc.time_s; ec += rc.energy_j
        tg += rg.time_s; eg += rg.energy_j
    return Fig9Result(
        speedup_vs_2d=t2 / t3,
        speedup_vs_cpu=tc / t3,
        speedup_vs_gpu=tg / t3,
        energy_saving_vs_2d=e2 / e3,
        energy_saving_vs_cpu=ec / e3,
        energy_saving_vs_gpu=eg / e3,
    )


def calibrate(
    workloads: tuple[ConvLayer, ...] = PAPER_WORKLOADS,
    spec: Stack3DSpec = Stack3DSpec(),
    base: HardwareConstants = HardwareConstants(),
    target: Fig9Result = PAPER_FIG9,
    iters: int = 60,
) -> HardwareConstants:
    """Solve the four [cal] knobs so the model reproduces the paper's four
    primary ratios.  Each knob is monotone in exactly one target, so simple
    1-D bisection per knob, iterated to joint convergence, suffices."""
    hw = base

    def ratios(h):
        return evaluate_fig9(workloads, spec, h)

    for _ in range(iters):
        r = ratios(hw)
        # fig8_lat_16 ~ speedup_vs_2d (inverse), eta_cpu ~ speedup_vs_cpu,
        # eta_gpu ~ speedup_vs_gpu, e_adc ~ energy_saving_vs_2d.
        hw = dataclasses.replace(
            hw,
            fig8_lat_16=hw.fig8_lat_16 * r.speedup_vs_2d / target.speedup_vs_2d,
            cpu_eta=hw.cpu_eta * r.speedup_vs_cpu / target.speedup_vs_cpu,
            gpu_eta=hw.gpu_eta * r.speedup_vs_gpu / target.speedup_vs_gpu,
        )
        r = ratios(hw)
        # e_adc moves the 2D/3D energy ratio toward the target: the 2D design
        # pays L x the conversions, so a larger e_adc widens the gap.
        err = target.energy_saving_vs_2d / r.energy_saving_vs_2d
        hw = dataclasses.replace(hw, e_adc_pJ=min(max(hw.e_adc_pJ * err, 0.5), 60.0))
        r = ratios(hw)
        # system_energy_scale sets the absolute 3D energy (tile periphery):
        # E_cpu/E_3d is inverse in it; the 2D/3D ratio is invariant.
        hw = dataclasses.replace(
            hw,
            system_energy_scale=hw.system_energy_scale
            * r.energy_saving_vs_cpu / target.energy_saving_vs_cpu,
        )
    return hw


def normalized_fig8(hw: HardwareConstants = DEFAULT_HW) -> list[dict]:
    """Paper Fig. 8: read/write latency & energy vs layers, normalized to 2L."""
    rows = []
    wr_nJ, rd_nJ, wr_ns, rd_ns = MEMORY_TABLE["ReRAM"]
    for layers in (2, 4, 6, 8, 10, 12, 14, 16):
        lf, ef = fig8_latency_factor(layers, hw), fig8_energy_factor(layers, hw)
        rows.append(
            dict(layers=layers,
                 read_latency=lf, write_latency=lf * wr_ns / rd_ns,
                 read_energy=ef, write_energy=ef * wr_nJ / rd_nJ)
        )
    return rows
