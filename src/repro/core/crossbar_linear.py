"""CrossbarLinear: run any LM linear projection through the simulated
ReRAM crossbar (PIM-style analog inference mode).

This is how the paper's technique becomes a first-class feature for the
assigned LM architectures whose compute is linear projections rather than
convolutions: weights are programmed onto (tiled) crossbars with the
paper's negative-weight separation scheme, inputs go through DACs, outputs
through op-amp subtraction + ADCs.  Used by the accuracy-equivalence
experiments (the paper claims "3D ReRAM achieves the same inference
accuracy as our baseline") and by ``examples/edge_detect_crossbar.py``.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .crossbar import CrossbarConfig, crossbar_vmm_tiled
from .mapping3d import Stack3DSpec


@dataclasses.dataclass(frozen=True)
class CrossbarLinearConfig:
    xbar: CrossbarConfig = CrossbarConfig()
    spec: Stack3DSpec = Stack3DSpec()


def crossbar_linear(
    x: jax.Array,
    weight: jax.Array,
    bias: jax.Array | None = None,
    cfg: CrossbarLinearConfig = CrossbarLinearConfig(),
) -> jax.Array:
    """y = x @ weight (+ bias) through the crossbar simulator.

    x: (..., d_in); weight: (d_in, d_out).  Tiles of
    (wl_per_plane x bl_per_plane) match the physical plane capacity."""
    out = crossbar_vmm_tiled(
        x.astype(jnp.float32),
        weight.astype(jnp.float32),
        cfg.xbar,
        tile_k=cfg.spec.wl_per_plane,
        tile_m=cfg.spec.bl_per_plane,
    )
    if bias is not None:
        out = out + bias
    return out.astype(x.dtype)


def quantization_error(
    x: jax.Array, weight: jax.Array, cfg: CrossbarLinearConfig = CrossbarLinearConfig()
) -> jax.Array:
    """Relative L2 error of the crossbar path vs exact matmul (the accuracy-
    equivalence metric used in tests)."""
    exact = x.astype(jnp.float32) @ weight.astype(jnp.float32)
    approx = crossbar_linear(x, weight, None, cfg).astype(jnp.float32)
    return jnp.linalg.norm(approx - exact) / jnp.maximum(jnp.linalg.norm(exact), 1e-30)
