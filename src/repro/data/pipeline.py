"""Deterministic, restart-safe data pipeline.

Design for 1000+ nodes: every batch is a pure function of (seed, step) --
no iterator state to checkpoint, restarts replay exactly, and each host
can materialize exactly its addressable shard (``host_slice``).  Two
sources:

  * SyntheticLM  -- Philox-counter synthetic tokens (benchmarks, dry-runs,
    tests).  Includes a learnable structure knob (Markov-ish bigram bias)
    so optimization tests can verify loss decreases.
  * TokenFileLM  -- memory-mapped flat token file (np.uint16/32) chunked
    deterministically by step; the production path.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    structure: float = 0.8   # 0 = iid uniform; >0 = predictable structure


class SyntheticLM:
    """Batches are f(seed, step): tokens (B, T+1) -> inputs/targets views."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def batch(self, step: int) -> dict[str, np.ndarray]:
        c = self.cfg
        rng = np.random.Generator(np.random.Philox(key=[c.seed, step]))
        b, t = c.global_batch, c.seq_len
        toks = rng.integers(0, c.vocab_size, size=(b, t + 1), dtype=np.int64)
        if c.structure > 0:
            # Deterministic bigram: token_{i+1} = (a*token_i + c0) % V with
            # probability `structure` -- learnable signal for train tests.
            a, c0 = 6364136223846793005 % c.vocab_size or 1, 1442695040888963407 % c.vocab_size
            follow = rng.random((b, t)) < c.structure
            for i in range(t):
                nxt = (toks[:, i] * a + c0) % c.vocab_size
                toks[:, i + 1] = np.where(follow[:, i], nxt, toks[:, i + 1])
        return {
            "tokens": toks[:, :-1].astype(np.int32),
            "targets": toks[:, 1:].astype(np.int32),
        }

    def host_slice(self, step: int, host_id: int, num_hosts: int) -> dict:
        """The shard a single host materializes (scale path: each host only
        builds its addressable rows)."""
        full = self.batch(step)
        b = self.cfg.global_batch
        lo = b * host_id // num_hosts
        hi = b * (host_id + 1) // num_hosts
        return {k: v[lo:hi] for k, v in full.items()}


class TokenFileLM:
    """Flat binary token file, deterministic step chunking."""

    def __init__(self, path: str, cfg: DataConfig, dtype=np.uint16):
        self.cfg = cfg
        self.data = np.memmap(path, dtype=dtype, mode="r")
        self.tokens_per_batch = cfg.global_batch * (cfg.seq_len + 1)
        if len(self.data) < self.tokens_per_batch:
            raise ValueError("token file smaller than one batch")

    def batch(self, step: int) -> dict[str, np.ndarray]:
        c = self.cfg
        n = len(self.data) - self.tokens_per_batch
        # Deterministic stride walk: decorrelates epochs without shuffling state.
        offset = (step * 2654435761 + c.seed) % max(n, 1)
        flat = np.asarray(self.data[offset: offset + self.tokens_per_batch])
        toks = flat.reshape(c.global_batch, c.seq_len + 1).astype(np.int64)
        toks %= c.vocab_size
        return {"tokens": toks[:, :-1].astype(np.int32),
                "targets": toks[:, 1:].astype(np.int32)}


def make_pipeline(cfg: DataConfig, path: str | None = None):
    return TokenFileLM(path, cfg) if path else SyntheticLM(cfg)
