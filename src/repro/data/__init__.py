"""Data pipelines."""
from .pipeline import DataConfig, SyntheticLM, TokenFileLM, make_pipeline
