"""Pallas TPU kernel: flash attention (fused online-softmax, VMEM state).

The §Perf analysis shows the pure-XLA flash lowering still pays O(s^2)
HBM traffic for score blocks (dot results materialize between kernels);
this kernel keeps the online-softmax state (m, l, acc) in VMEM scratch
across the KV grid dimension, so HBM sees only q/k/v/out.  Causal BLOCK
SKIP: fully-masked KV blocks are skipped with pl.when -- the pure-jnp
path multiplies by a zero mask instead (2x wasted MXU work on causal
attention, visible as HLO flops in the roofline).

Layout: q (bh, sq, d), k/v (bh, skv, d) -- GQA expanded by ops.py.
Grid = (bh, q_tiles, kv_tiles), kv innermost (revisits the output tile).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, out_ref, acc_ref, m_ref, l_ref,
            *, tq, tk, d, causal):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q0 = qi * tq
    k0 = ki * tk

    def _update():
        q = q_ref[0].astype(jnp.float32)            # (TQ, D)
        k = k_ref[0].astype(jnp.float32)            # (TK, D)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * (d ** -0.5)  # (TQ, TK)
        if causal:
            qpos = q0 + jax.lax.broadcasted_iota(jnp.int32, (tq, tk), 0)
            kpos = k0 + jax.lax.broadcasted_iota(jnp.int32, (tq, tk), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_ref[...]                          # (TQ, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    if causal:
        # Causal block skip: if every key in this block is in the future of
        # every query in the q tile, skip the whole block (real flops saving
        # on TPU; the pure-jnp path only masks -- it still pays the MXU).
        pl.when(k0 <= q0 + tq - 1)(_update)
    else:
        _update()

    @pl.when(ki == nk - 1)
    def _write():
        out_ref[0] = (acc_ref[...] /
                      jnp.maximum(l_ref[...], 1e-30)).astype(out_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "tq", "tk", "interpret"))
def flash_attention_bhsd(
    q: jax.Array,      # (bh, sq, d)
    k: jax.Array,      # (bh, skv, d)
    v: jax.Array,      # (bh, skv, d)
    *,
    causal: bool = True,
    tq: int = 128,
    tk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    bh, sq, d = q.shape
    _, skv, _ = k.shape
    if sq % tq or skv % tk:
        raise ValueError(f"(sq={sq}, skv={skv}) not divisible by ({tq}, {tk})")
    grid = (bh, sq // tq, skv // tk)
    kernel = functools.partial(_kernel, tq=tq, tk=tk, d=d, causal=causal)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, tk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, tk, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, tq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((tq, d), jnp.float32),
            pltpu.VMEM((tq, 1), jnp.float32),
            pltpu.VMEM((tq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
