"""Pure-jnp oracle for the flash attention kernel."""

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal=True):
    """q/k/v: (bh, s, d) -> (bh, sq, d), fp32 softmax."""
    bh, sq, d = q.shape
    skv = k.shape[1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (d ** -0.5)
    if causal:
        qpos = jnp.arange(sq)[:, None]
        kpos = jnp.arange(skv)[None, :]
        s = jnp.where(kpos <= qpos, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)
