"""jit wrapper: GQA expansion, (b, h, s, d) public layout, padding,
interpret fallback."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import flash_attention_bhsd


def _round_up(x, m):
    return -(-x // m) * m


def flash_attention(
    q: jax.Array,      # (b, hq, sq, d)
    k: jax.Array,      # (b, hkv, skv, d)
    v: jax.Array,
    *,
    causal: bool = True,
    tq: int | None = None,
    tk: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    g = hq // hkv
    if g > 1:  # GQA: expand kv heads (kernel is MHA-shaped)
        k = jnp.repeat(k, g, axis=1)
        v = jnp.repeat(v, g, axis=1)
    tq = tq or min(128, sq)
    tk = tk or min(128, skv)
    sqp, skp = _round_up(sq, tq), _round_up(skv, tk)
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, sqp - sq), (0, 0))).reshape(b * hq, sqp, d)
    # Pad KEYS so padded positions never win the softmax: since queries at
    # padded rows are discarded and causal masking handles kpos > qpos,
    # only non-causal padding needs care -- mask via large-negative k? We
    # instead rely on padded kpos > any real qpos under causal=True, and
    # for causal=False we pad skv only when necessary and mask below.
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, skp - skv), (0, 0))).reshape(b * hq, skp, d)
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, skp - skv), (0, 0))).reshape(b * hq, skp, d)
    if not causal and skp != skv:
        raise ValueError("non-causal flash requires skv divisible by tk")
    out = flash_attention_bhsd(qp, kp, vp, causal=causal, tq=tq, tk=tk,
                               interpret=interpret)
    return out.reshape(b, hq, sqp, d)[:, :, :sq]
