"""jit wrapper: padding, interpret fallback, and a convenience path from
signed weights (programs conductances like core.crossbar)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ...core import crossbar as xbar
from .kernel import crossbar_vmm_pallas


def _round_up(x, m):
    return -(-x // m) * m


def crossbar_vmm(
    v: jax.Array, g_pos: jax.Array, g_neg: jax.Array, i_range: jax.Array,
    *, adc_bits: int = 10, tm: int | None = None, tn: int | None = None,
    tk: int | None = None, interpret: bool | None = None,
) -> jax.Array:
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    m, k = v.shape
    _, n = g_pos.shape
    tm = tm or min(128, m)
    tn = tn or min(128, n)
    tk = tk or min(128, k)
    mp, kp, npad = _round_up(m, tm), _round_up(k, tk), _round_up(n, tn)
    vp = jnp.pad(v, ((0, mp - m), (0, kp - k)))
    gp = jnp.pad(g_pos, ((0, kp - k), (0, npad - n)))
    gn = jnp.pad(g_neg, ((0, kp - k), (0, npad - n)))
    out = crossbar_vmm_pallas(vp, gp, gn, i_range.reshape(1),
                              adc_bits=adc_bits, tm=tm, tn=tn, tk=tk,
                              interpret=interpret)
    return out[:m, :n]


def crossbar_linear_pallas(
    x: jax.Array, w: jax.Array, cfg: xbar.CrossbarConfig = xbar.CrossbarConfig(),
    **kw,
) -> jax.Array:
    """Drop-in signed-weight entry point: programs conductances with the
    paper's separation scheme, runs the fused kernel, restores scales."""
    g_pos, g_neg, w_scale = xbar.program_conductances(
        w, xbar.CrossbarConfig(weight_bits=cfg.weight_bits,
                               g_on_off_ratio=1e9))
    v, x_scale = xbar.dac_quantize(x, cfg)
    i_range = jnp.maximum(jnp.sum(g_pos, axis=0).max(),
                          jnp.sum(g_neg, axis=0).max()).reshape(1)
    lead = x.shape[:-1]
    out = crossbar_vmm(v.reshape(-1, x.shape[-1]), g_pos, g_neg, i_range,
                       adc_bits=cfg.adc_bits, **kw)
    return (out * (w_scale * x_scale)).reshape(*lead, w.shape[1])
