"""Pure-jnp oracle: the same signal chain as core.crossbar, unfused."""

import jax.numpy as jnp


def crossbar_vmm_ref(v, g_pos, g_neg, i_range, adc_bits: int = 10):
    ip = v.astype(jnp.float32) @ g_pos.astype(jnp.float32)
    i_n = v.astype(jnp.float32) @ g_neg.astype(jnp.float32)
    i_diff = ip - i_n
    levels = (1 << adc_bits) - 1
    fs = i_range.reshape(())
    q = jnp.round(jnp.clip(i_diff / fs, -1.0, 1.0) * levels) / levels
    return q * fs
