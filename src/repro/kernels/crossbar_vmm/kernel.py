"""Pallas TPU kernel: crossbar VMM with negative-weight separation.

The MXU rendition of the paper's signal chain (§III.C/D): the positive
and negative conductance planes multiply the (DAC-quantized) drive
matrix, the two partial currents accumulate in VMEM across k-tiles
(Kirchhoff along the bit line), the op-amp subtraction I_p - I_n and the
single ADC quantization happen IN VMEM on the final k step -- one HBM
writeback per output, no per-tap conversions (the 3D design's energy
story, here the memory-traffic story).

Grid = (m_tiles, n_tiles, k_tiles), k innermost (revisiting accumulate).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(v_ref, gp_ref, gn_ref, irange_ref, out_ref, accp, accn,
            *, adc_levels):
    kc = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(kc == 0)
    def _init():
        accp[...] = jnp.zeros_like(accp)
        accn[...] = jnp.zeros_like(accn)

    v = v_ref[...].astype(jnp.float32)
    accp[...] += jax.lax.dot(v, gp_ref[...].astype(jnp.float32),
                             preferred_element_type=jnp.float32)
    accn[...] += jax.lax.dot(v, gn_ref[...].astype(jnp.float32),
                             preferred_element_type=jnp.float32)

    @pl.when(kc == nk - 1)
    def _opamp_adc():
        i_diff = accp[...] - accn[...]            # op-amp: I2 = I_p - I_n
        fs = irange_ref[0]                        # ADC full-scale current
        q = jnp.round(jnp.clip(i_diff / fs, -1.0, 1.0) * adc_levels) / adc_levels
        out_ref[...] = (q * fs).astype(out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("adc_bits", "tm", "tn", "tk", "interpret"))
def crossbar_vmm_pallas(
    v: jax.Array,         # (m, k) DAC-quantized drive
    g_pos: jax.Array,     # (k, n) non-negative conductances
    g_neg: jax.Array,     # (k, n)
    i_range: jax.Array,   # (1,) ADC full-scale
    *,
    adc_bits: int = 10,
    tm: int = 128,
    tn: int = 128,
    tk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    m, k = v.shape
    _, n = g_pos.shape
    if m % tm or n % tn or k % tk:
        raise ValueError(f"(m={m}, k={k}, n={n}) not divisible by "
                         f"({tm}, {tk}, {tn}); ops.py pads first")
    adc_levels = (1 << adc_bits) - 1
    return pl.pallas_call(
        functools.partial(_kernel, adc_levels=adc_levels),
        grid=(m // tm, n // tn, k // tk),
        in_specs=[
            pl.BlockSpec((tm, tk), lambda i, j, kc: (i, kc)),
            pl.BlockSpec((tk, tn), lambda i, j, kc: (kc, j)),
            pl.BlockSpec((tk, tn), lambda i, j, kc: (kc, j)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, kc: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((tm, tn), jnp.float32),
                        pltpu.VMEM((tm, tn), jnp.float32)],
        interpret=interpret,
    )(v, g_pos, g_neg, i_range)
