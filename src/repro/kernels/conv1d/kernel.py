"""Pallas TPU kernel: fused causal depthwise conv1d (kn2row 1-D form).

The 1-D specialization of the paper's mapping used inside the xLSTM and
RG-LRU blocks: each of the `l` taps is a diagonal plane; the shifted
partials accumulate in VMEM and hit HBM once.  VPU (elementwise) work.

Layout: x pre-padded left by l-1: (b, t + l - 1, c); weight (l, c).
Grid = (b, t_tiles, c_tiles).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_hbm, w_ref, out_ref, *, l, tt, ct):
    bi = pl.program_id(0)
    ti = pl.program_id(1)
    ci = pl.program_id(2)

    acc = jnp.zeros((tt, ct), jnp.float32)
    # Tap i reads x[t - (l - 1) + i]; with left-pad l-1 the slab for output
    # tile start T0 is x_padded[T0 + i : T0 + i + TT].
    for i in range(l):
        slab = pl.load(
            x_hbm,
            (bi, pl.dslice(ti * tt + i, tt), pl.dslice(ci * ct, ct)))
        acc += slab.astype(jnp.float32) * w_ref[i].astype(jnp.float32)
    out_ref[...] = acc.reshape(out_ref.shape).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("l", "tt", "ct", "interpret"))
def conv1d_causal_padded(
    x_padded: jax.Array,     # (b, t + l - 1, c)
    weight: jax.Array,       # (l, c)
    *,
    l: int,
    tt: int = 128,
    ct: int = 128,
    interpret: bool = False,
) -> jax.Array:
    b, tp, c = x_padded.shape
    t = tp - l + 1
    if t % tt or c % ct:
        raise ValueError(f"(t={t}, c={c}) not divisible by tiles ({tt}, {ct})")
    return pl.pallas_call(
        functools.partial(_kernel, l=l, tt=tt, ct=ct),
        grid=(b, t // tt, c // ct),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),          # overlapping slabs
            pl.BlockSpec((l, ct), lambda bi, ti, ci: (0, ci)),
        ],
        out_specs=pl.BlockSpec((1, tt, ct), lambda bi, ti, ci: (bi, ti, ci)),
        out_shape=jax.ShapeDtypeStruct((b, t, c), x_padded.dtype),
        interpret=interpret,
    )(x_padded, weight)
