"""Pure-jnp oracle for the causal conv1d kernel."""

from ...core.kn2row import conv1d_depthwise_causal_ref


def conv1d_causal_ref(x, weight):
    """x (b, t, c), weight (l, c) -> (b, t, c)."""
    return conv1d_depthwise_causal_ref(x, weight)
