"""jit wrapper for the causal conv1d kernel: padding, tiles, interpret."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import conv1d_causal_padded


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def conv1d_causal(
    x: jax.Array,          # (b, t, c)
    weight: jax.Array,     # (l, c)
    *,
    tt: int | None = None,
    ct: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, t, c = x.shape
    l = weight.shape[0]
    tt = tt or min(128, t)
    ct = ct or min(128, c)
    tp, cp = _round_up(t, tt), _round_up(c, ct)
    xp = jnp.pad(x, ((0, 0), (l - 1, tp - t), (0, cp - c)))
    wp = jnp.pad(weight, ((0, 0), (0, cp - c)))
    out = conv1d_causal_padded(xp, wp, l=l, tt=tt, ct=ct, interpret=interpret)
    return out[:, :t, :c]
