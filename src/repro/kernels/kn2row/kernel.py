"""Pallas TPU kernel: fused kn2row multi-channel convolution.

The paper's mapping, transliterated to the TPU memory hierarchy: each of
the l1*l2 kernel taps is a [C, N] matmul plane (one "memristor layer");
the tap partials for an output tile are accumulated in a fp32 VMEM
scratch (the analog current-plane superimposition of paper eq. (1)) and
written back to HBM exactly once -- the l1*l2 partial feature maps never
exist in HBM, which is the whole point of the 3D mapping.

Layout: image NHWC, pre-padded by ops.py to (b, h+l1-1, w+l2-1, c);
weights reshaped to (l1*l2, c, n).  Grid = (b, h_tiles, w_tiles,
c_tiles); the c (k-dim) tiles revisit the same output tile, innermost,
accumulating; the tap loop is unrolled inside the kernel (static l1*l2,
the "stack depth").  MXU work per grid step: l1*l2 GEMMs of
[TH*TW, CT] x [CT, N].
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(in_hbm, w_ref, out_ref, acc_ref, *, l1, l2, th, tw, ct, c_total):
    bi = pl.program_id(0)
    ti = pl.program_id(1)
    tj = pl.program_id(2)
    kc = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(kc == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # One tap = one "memristor layer": shifted input slab x [CT, N] weights,
    # superimposed into the VMEM accumulator (eq. (1) analogue).
    for dy in range(l1):
        for dx in range(l2):
            tap = dy * l2 + dx
            slab = pl.load(
                in_hbm,
                (bi,
                 pl.dslice(ti * th + dy, th),
                 pl.dslice(tj * tw + dx, tw),
                 pl.dslice(kc * ct, ct)),
            )  # (TH, TW, CT)
            mat = slab.reshape(th * tw, ct).astype(jnp.float32)
            acc_ref[...] += jax.lax.dot(
                mat, w_ref[tap].astype(jnp.float32),
                precision=jax.lax.Precision.DEFAULT,
                preferred_element_type=jnp.float32)

    @pl.when(kc == nk - 1)
    def _write():
        out_ref[...] = acc_ref[...].reshape(out_ref.shape).astype(out_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("l1", "l2", "th", "tw", "ct", "interpret"))
def kn2row_conv_padded(
    image_padded: jax.Array,   # (b, h + l1 - 1, w + l2 - 1, c) NHWC
    weights: jax.Array,        # (l1*l2, c, n)
    *,
    l1: int,
    l2: int,
    th: int = 8,
    tw: int = 16,
    ct: int = 128,
    interpret: bool = False,
) -> jax.Array:
    b, hp, wp, c = image_padded.shape
    taps, _, n = weights.shape
    h, w = hp - l1 + 1, wp - l2 + 1
    if taps != l1 * l2:
        raise ValueError(f"weights taps {taps} != l1*l2 {l1 * l2}")
    if h % th or w % tw or c % ct:
        raise ValueError(f"(h={h}, w={w}, c={c}) not divisible by tiles "
                         f"({th}, {tw}, {ct}); ops.py pads first")

    grid = (b, h // th, w // tw, c // ct)
    kernel = functools.partial(_kernel, l1=l1, l2=l2, th=th, tw=tw, ct=ct,
                               c_total=c)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            # Full padded image stays in HBM/ANY; taps use dynamic slices
            # (overlapping slabs cannot be expressed as disjoint blocks).
            pl.BlockSpec(memory_space=pltpu.ANY),
            # Weight plane stack: all taps for this c-tile, resident in VMEM.
            pl.BlockSpec((taps, ct, n), lambda bi, i, j, kc: (0, kc, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, th, tw, n), lambda bi, i, j, kc: (bi, i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, w, n), image_padded.dtype),
        scratch_shapes=[pltpu.VMEM((th * tw, n), jnp.float32)],
        interpret=interpret,
    )(image_padded, weights)
