"""jit wrapper for the kn2row kernel: NCHW public API, padding/layout
management, tile-size selection, CPU interpret fallback."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import kn2row_conv_padded


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def kn2row_conv(
    image: jax.Array,      # (b, c, h, w)
    kernel: jax.Array,     # (n, c, l1, l2)
    *,
    th: int | None = None,
    tw: int | None = None,
    ct: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """SAME-padding stride-1 MKMC convolution via the fused Pallas kernel.

    Handles layout (NCHW <-> NHWC), SAME padding, and pads h/w/c up to tile
    multiples (masked back off afterwards)."""
    if interpret is None:
        interpret = not _on_tpu()
    b, c, h, w = image.shape
    n, _, l1, l2 = kernel.shape

    th = th or min(8, h)
    tw = tw or min(128 if w >= 128 else 16, w)
    ct = ct or min(128, c)

    hp, wp, cp = _round_up(h, th), _round_up(w, tw), _round_up(c, ct)
    # NHWC + SAME halo + tile padding.
    x = jnp.transpose(image, (0, 2, 3, 1))
    x = jnp.pad(x, ((0, 0),
                    ((l1 - 1) // 2, l1 // 2 + (hp - h)),
                    ((l2 - 1) // 2, l2 // 2 + (wp - w)),
                    (0, 0, ) if cp == c else (0, cp - c)))
    wmat = jnp.transpose(kernel, (2, 3, 1, 0)).reshape(l1 * l2, c, n)
    if cp != c:
        wmat = jnp.pad(wmat, ((0, 0), (0, cp - c), (0, 0)))

    out = kn2row_conv_padded(x, wmat, l1=l1, l2=l2, th=th, tw=tw, ct=ct,
                             interpret=interpret)
    out = out[:, :h, :w, :]
    return jnp.transpose(out, (0, 3, 1, 2))
