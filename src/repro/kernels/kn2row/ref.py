"""Pure-jnp oracle for the kn2row Pallas kernel (NCHW public interface)."""

import jax

from ...core.kn2row import conv2d_direct


def kn2row_conv_ref(image: jax.Array, kernel: jax.Array) -> jax.Array:
    """image (b, c, h, w), kernel (n, c, l1, l2) -> (b, n, h, w), SAME."""
    return conv2d_direct(image, kernel, padding="SAME")
