"""Pallas TPU kernels for the paper's compute hot spots.

Each kernel ships kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd
public wrapper, interpret fallback on CPU) and ref.py (pure-jnp oracle);
tests sweep shapes/dtypes against the oracle in interpret mode.
"""
from .kn2row.ops import kn2row_conv
from .conv1d.ops import conv1d_causal
from .crossbar_vmm.ops import crossbar_linear_pallas, crossbar_vmm
from .flash.ops import flash_attention
