"""Shared model building blocks: norms, RoPE/M-RoPE, activations, init,
and the mesh-aware sharding-constraint helper used throughout the zoo.

All models are pure-functional: params are plain nested-dict pytrees,
``init_*`` builds them, ``apply``-style functions consume them.  Leaf
arrays are annotated with *logical axes* via the parallel ``*_axes``
functions in each model module; ``repro.dist.sharding`` maps logical ->
mesh axes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


# ----------------------------- sharding helper -----------------------------


def mesh_axis_names() -> tuple[str, ...]:
    m = jax.sharding.get_abstract_mesh()
    return () if m.empty else m.axis_names


def wsc(x: jax.Array, *logical: object) -> jax.Array:
    """with_sharding_constraint that degrades to a no-op without a mesh.

    ``logical`` entries are mesh-axis names, tuples of names, or None.
    Names absent from the current mesh are dropped (e.g. 'pod' on the
    single-pod mesh) and axes that do not divide the dimension are dropped
    (small archs on big meshes stay replicated rather than failing), so one
    annotation works for every mesh."""
    m = jax.sharding.get_abstract_mesh()
    if m.empty:
        return x
    names = m.axis_names
    sizes = dict(zip(names, m.axis_sizes))
    spec = []
    for dim, entry in zip(x.shape, logical):
        cand = (entry,) if isinstance(entry, str) else (entry or ())
        kept: list[str] = []
        total = 1
        for a in cand:
            if a in names and dim % (total * sizes[a]) == 0:
                kept.append(a)
                total *= sizes[a]
        spec.append(tuple(kept) if len(kept) > 1 else (kept[0] if kept else None))
    return jax.lax.with_sharding_constraint(x, P(*spec))


BATCH = ("pod", "data")  # logical batch axis spans pod x data


# ------------------------------- numerics ----------------------------------


def dtype_of(name: str) -> jnp.dtype:
    return jnp.dtype({"float32": jnp.float32, "bfloat16": jnp.bfloat16,
                      "float16": jnp.float16}[name])


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layernorm(x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def norm(x, params, cfg):
    if cfg.norm_type == "layernorm":
        return layernorm(x, params["scale"], params["bias"], cfg.norm_eps)
    return rmsnorm(x, params["scale"], cfg.norm_eps)


def norm_init(cfg, d: int) -> dict:
    if cfg.norm_type == "layernorm":
        return {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)}
    return {"scale": jnp.zeros((d,), jnp.float32)}  # rmsnorm stores (scale - 1)


def act_fn(name: str):
    return {
        "silu": jax.nn.silu,
        "gelu": jax.nn.gelu,
        "relu2": lambda x: jnp.square(jax.nn.relu(x)),
        "relu": jax.nn.relu,
    }[name]


def softcap(x: jax.Array, cap: float) -> jax.Array:
    return jnp.tanh(x / cap) * cap if cap > 0 else x


# ------------------------------ initializers -------------------------------


def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32) -> jax.Array:
    """Truncated-normal fan-in init (LeCun-style), the zoo default."""
    scale = d_in ** -0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, (d_in, d_out)) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype=jnp.float32) -> jax.Array:
    return (jax.random.normal(key, (vocab, d)) * (d ** -0.5)).astype(dtype)


# --------------------------------- RoPE -------------------------------------


def rope_angles(positions: jax.Array, head_dim: int, theta: float) -> tuple[jax.Array, jax.Array]:
    """positions: (..., t) int -> cos/sin of shape (..., t, head_dim//2)."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (b, t, h, d). cos/sin: (b, t, d/2) or (t, d/2). Rotate-half form."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    if cos.ndim == 2:
        cos_, sin_ = cos[None, :, None, :], sin[None, :, None, :]
    else:
        cos_, sin_ = cos[:, :, None, :], sin[:, :, None, :]
    out = jnp.concatenate([x1 * cos_ - x2 * sin_, x2 * cos_ + x1 * sin_], axis=-1)
    return out.astype(x.dtype)


def mrope_angles(
    positions: jax.Array, sections: tuple[int, int, int], head_dim: int, theta: float
) -> tuple[jax.Array, jax.Array]:
    """Qwen2-VL M-RoPE: positions (3, b, t) for (temporal, height, width);
    the head_dim/2 frequency slots are split into three contiguous sections,
    each rotated by its own position stream."""
    half = head_dim // 2
    if sum(sections) != half:
        raise ValueError(f"mrope sections {sections} must sum to head_dim/2={half}")
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (3, b, t, half)
    idx = jnp.concatenate(
        [jnp.full((s,), i, dtype=jnp.int32) for i, s in enumerate(sections)]
    )  # (half,) section selector
    ang = jnp.take_along_axis(
        ang, idx[None, None, None, :].repeat(ang.shape[1], 1).repeat(ang.shape[2], 2), axis=0
    )[0]  # (b, t, half)
    return jnp.cos(ang), jnp.sin(ang)


def default_positions(b: int, t: int, offset: jax.Array | int = 0) -> jax.Array:
    return jnp.arange(t, dtype=jnp.int32)[None, :] + jnp.zeros((b, 1), jnp.int32) + offset
