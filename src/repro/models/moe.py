"""Mixture-of-Experts layer: token-choice top-k routing with capacity,
sort-based dispatch (no [tokens, experts, capacity] one-hot blowup), and
expert-parallel-friendly [E, C, D] batched-GEMM compute.

Dispatch pipeline (all static shapes, jit-safe):
  1. router logits -> top-k (expert_id, gate) per token
  2. flatten to T*k assignments, sort by expert_id
  3. position-within-expert via sorted-segment cumsum; drop > capacity
  4. scatter tokens into an [E, C, D] buffer
  5. batched GEMM per expert stack (shardable: E over the 'model'/'expert' axis)
  6. gather back, weight by gates, sum the k contributions

The aux load-balancing loss (Switch-style) is returned via a side channel
(``moe_apply`` accumulates into ``aux_loss_store`` when provided).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import BATCH, dense_init, wsc


def moe_init(key, cfg) -> dict:
    kr, k1, k2, k3 = jax.random.split(key, 4)
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    return {
        "router": dense_init(kr, d, e),
        "wi": jax.vmap(lambda k: dense_init(k, d, f))(jax.random.split(k1, e)),
        "wg": jax.vmap(lambda k: dense_init(k, d, f))(jax.random.split(k2, e)),
        "wo": jax.vmap(lambda k: dense_init(k, f, d))(jax.random.split(k3, e)),
    }


def moe_axes(cfg) -> dict:
    return {
        "router": ("embed", None),
        "wi": ("experts", "embed", "mlp"),
        "wg": ("experts", "embed", "mlp"),
        "wo": ("experts", "mlp", "embed"),
    }


def _capacity(cfg, num_tokens: int) -> int:
    c = int(cfg.expert_capacity_factor * num_tokens * cfg.num_experts_per_token
            / cfg.num_experts)
    return max(8, -(-c // 8) * 8)  # round up to 8 for layout friendliness


def moe_apply(params, cfg, x, *, aux_loss_store: list | None = None) -> jax.Array:
    """x: (b, t, d) -> (b, t, d).

    With cfg.moe_dispatch_groups = G > 0 the routing/sort/capacity pipeline
    runs independently in G token groups (vmapped).  Groups align with the
    batch shards, so the argsort and position-cumsum never cross devices --
    the baseline's GLOBAL argsort over (pod x data)-sharded tokens is the
    single largest collective in the MoE train cells (§Perf)."""
    if getattr(cfg, "moe_shard_map", False):
        y = _moe_apply_shard_map(params, cfg, x)
        if y is not None:
            if aux_loss_store is not None:
                _moe_aux_only(params, cfg, x, aux_loss_store)
            return y
    g = getattr(cfg, "moe_dispatch_groups", 0)
    if g and (x.shape[0] * x.shape[1]) % g == 0:
        b, t, d = x.shape
        xg = x.reshape(g, (b * t) // g, 1, d)
        xg = wsc(xg, ("pod", "data"), None, None, None)  # groups = batch shards
        yg = jax.vmap(
            lambda xx: _moe_apply_flat(params, cfg, xx,
                                       aux_loss_store=None))(xg)
        if aux_loss_store is not None:
            # balance loss still computed globally (cheap, fp32 router only)
            _moe_aux_only(params, cfg, x, aux_loss_store)
        return yg.reshape(b, t, d)
    return _moe_apply_flat(params, cfg, x, aux_loss_store=aux_loss_store)


def _moe_apply_shard_map(params, cfg, x):
    """Routing/dispatch/combine MANUALLY sharded over the batch axes via
    shard_map (indices provably shard-local, so the gathers' backward
    scatter-adds stay local too -- the vmapped-groups formulation still
    leaks fp32 all-reduces there, §Perf H4); the expert FFN inside stays
    AUTO over 'model' (EP via XLA collectives).  Returns None when no
    usable mesh is in context (tests / single device)."""
    from jax.sharding import PartitionSpec as P

    m = jax.sharding.get_abstract_mesh()
    if m.empty:
        return None
    names = tuple(n for n in ("pod", "data") if n in m.axis_names)
    if not names:
        return None
    shards = 1
    for n in names:
        shards *= dict(zip(m.axis_names, m.axis_sizes))[n]
    b, t, d = x.shape
    if b % shards != 0:
        return None

    def local_fn(x_loc, router, wi, wg, wo):
        p_loc = {"router": router, "wi": wi, "wg": wg, "wo": wo}
        return _moe_apply_flat(p_loc, cfg, x_loc)

    pspec = jax.tree.map(lambda _: P(), params)
    return jax.shard_map(
        local_fn,
        in_specs=(P(names if len(names) > 1 else names[0], None, None),
                  pspec["router"], pspec["wi"], pspec["wg"], pspec["wo"]),
        out_specs=P(names if len(names) > 1 else names[0], None, None),
        axis_names=set(names),
    )(x, params["router"], params["wi"], params["wg"], params["wo"])


def _moe_aux_only(params, cfg, x, aux_loss_store: list):
    b, t, d = x.shape
    logits = x.reshape(-1, d).astype(jnp.float32) @ params["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    _, expert_ids = jax.lax.top_k(probs, cfg.num_experts_per_token)
    n = probs.shape[0]
    e = cfg.num_experts
    me = probs.mean(axis=0)
    ce = jnp.zeros((e,), jnp.float32).at[expert_ids.reshape(-1)].add(1.0) / (
        n * cfg.num_experts_per_token)
    aux_loss_store.append(e * jnp.sum(me * ce))


def _moe_apply_flat(params, cfg, x, *, aux_loss_store: list | None = None) -> jax.Array:
    b, t, d = x.shape
    e, k = cfg.num_experts, cfg.num_experts_per_token
    ct = x.dtype
    xt = x.reshape(b * t, d)
    n = b * t
    cap = _capacity(cfg, n)

    # 1. Routing (fp32 for softmax stability).
    logits = (xt.astype(jnp.float32) @ params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                     # (n, e)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)             # (n, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    if aux_loss_store is not None:
        # Switch-transformer load-balance loss: e * sum_e f_e * p_e.
        me = probs.mean(axis=0)
        ce = jnp.zeros((e,), jnp.float32).at[expert_ids.reshape(-1)].add(1.0) / (n * k)
        aux_loss_store.append(e * jnp.sum(me * ce))

    # 2. Flatten assignments and sort by expert.
    flat_expert = expert_ids.reshape(-1)                        # (n*k,)
    flat_token = jnp.repeat(jnp.arange(n), k)
    flat_gate = gate_vals.reshape(-1)
    order = jnp.argsort(flat_expert, stable=True)
    s_expert = flat_expert[order]
    s_token = flat_token[order]
    s_gate = flat_gate[order]

    # 3. Position within expert segment = index - start_of_segment.
    idx = jnp.arange(n * k)
    counts = jnp.zeros((e,), jnp.int32).at[s_expert].add(1)
    seg_start = jnp.cumsum(counts) - counts                     # (e,)
    pos_in_seg = idx - seg_start[s_expert]
    keep = pos_in_seg < cap

    # 4. Build the [E*C] slot->token map with an INT scatter (width 1), then
    #    GATHER the tokens.  A d-wide `.at[slot].set(tokens)` scatter lowers
    #    to full-buffer fp32+u32 all-reduce combines under SPMD (measured
    #    ~640 GB/device on phi3.5-moe train -- §Perf H3); the int scatter +
    #    gather formulation keeps all d-sized traffic in gathers.
    slot = jnp.where(keep, s_expert * cap + pos_in_seg, e * cap)  # OOB=drop
    token_map = jnp.zeros((e * cap,), jnp.int32).at[slot].set(
        s_token.astype(jnp.int32), mode="drop")
    valid = jnp.zeros((e * cap,), bool).at[slot].set(True, mode="drop")
    buf = jnp.where(valid[:, None], xt[token_map].astype(ct), 0)
    buf = buf.reshape(e, cap, d)
    # Pin EP sharding: experts ride the 'model' mesh axis (when divisible),
    # so tokens FLOW to the expert shards (all-to-all) instead of XLA
    # all-gathering the expert weight stacks (§Perf H2).
    buf = wsc(buf, "model", None, None)

    # 5. Per-expert FFN (batched GEMM over the expert axis).
    h = jnp.einsum("ecd,edf->ecf", buf, params["wi"].astype(ct))
    g = jnp.einsum("ecd,edf->ecf", buf, params["wg"].astype(ct))
    h = jax.nn.silu(h) * g
    h = wsc(h, "model", None, None)
    out_buf = jnp.einsum("ecf,efd->ecd", h, params["wo"].astype(ct))
    out_buf = wsc(out_buf, "model", None, None)

    # 6. Gather back, gate, and combine the k expert contributions with an
    #    inverse-permutation gather + reshape-sum (no d-wide scatter-add:
    #    s_token repeats k times per token, which otherwise forces a
    #    duplicate-combining scatter -> full-buffer all-reduce under SPMD).
    flat_out = out_buf.reshape(e * cap, d)
    gathered = jnp.where(
        keep[:, None], flat_out[jnp.clip(slot, 0, e * cap - 1)], 0.0)
    contrib = gathered * s_gate[:, None].astype(ct)
    inv_order = jnp.argsort(order)          # assignment -> sorted position
    y = contrib[inv_order].reshape(n, k, d).sum(axis=1)
    return wsc(y.reshape(b, t, d), BATCH, None, None)


def moe_apply_dense_fallback(params, cfg, x) -> jax.Array:
    """Reference: run every expert on every token, weight by full softmax of
    the top-k-masked router -- used by tests as the numerical oracle."""
    b, t, d = x.shape
    ct = x.dtype
    logits = x.reshape(-1, d).astype(jnp.float32) @ params["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, cfg.num_experts_per_token)
    mask = jnp.zeros_like(probs).at[jnp.arange(probs.shape[0])[:, None], topi].set(1.0)
    gates = probs * mask
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    xt = x.reshape(-1, d)
    h = jnp.einsum("nd,edf->enf", xt, params["wi"].astype(ct))
    g = jnp.einsum("nd,edf->enf", xt, params["wg"].astype(ct))
    y = jnp.einsum("enf,efd->end", jax.nn.silu(h) * g, params["wo"].astype(ct))
    out = jnp.einsum("end,ne->nd", y, gates.astype(ct))
    return out.reshape(b, t, d)
