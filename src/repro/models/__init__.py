"""Model zoo: pure-functional JAX implementations of the assigned
architecture families (dense/MoE/VLM transformer, xLSTM, RG-LRU hybrid,
encoder-decoder)."""

from .registry import ModelAPI, get_model
