"""Decoder-only transformer trunk (dense + MoE + VLM families).

Pure-functional, scan-over-layers with stacked params (HLO depth O(1)),
logical-axis annotations via ``*_axes`` mirrors of the param trees.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import moe as moe_mod
from .attention import attn_apply, attn_axes, attn_cache_spec, attn_init
from .common import (
    BATCH,
    act_fn,
    default_positions,
    dense_init,
    dtype_of,
    embed_init,
    norm,
    norm_init,
    rope_angles,
    softcap,
    wsc,
)

# ------------------------------- MLP ----------------------------------------


def mlp_init(key, cfg) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    d, f = cfg.d_model, cfg.d_ff
    if cfg.mlp_type in ("swiglu", "geglu"):
        return {"wi": dense_init(k1, d, f), "wg": dense_init(k2, d, f),
                "wo": dense_init(k3, f, d)}
    return {"wi": dense_init(k1, d, f), "wo": dense_init(k3, f, d)}


def mlp_axes(cfg) -> dict:
    if cfg.mlp_type in ("swiglu", "geglu"):
        return {"wi": ("embed", "mlp"), "wg": ("embed", "mlp"), "wo": ("mlp", "embed")}
    return {"wi": ("embed", "mlp"), "wo": ("mlp", "embed")}


def mlp_apply(params, cfg, x):
    ct = x.dtype
    h = x @ params["wi"].astype(ct)
    if cfg.mlp_type == "swiglu":
        h = jax.nn.silu(h) * (x @ params["wg"].astype(ct))
    elif cfg.mlp_type == "geglu":
        h = jax.nn.gelu(h) * (x @ params["wg"].astype(ct))
    elif cfg.mlp_type == "relu2":
        h = jnp.square(jax.nn.relu(h))
    else:
        h = act_fn(cfg.mlp_type)(h)
    # pin the gated hidden to the model axis: without this XLA may
    # all-gather the fp32-converted d_ff activation (430 GB/device on
    # qwen1.5 prefill -- §Perf H7); "model" is the MESH axis name
    h = wsc(h, BATCH, None, "model")
    return wsc(h @ params["wo"].astype(ct), BATCH, None, None)


# ------------------------------ block ---------------------------------------


def block_init(key, cfg) -> dict:
    ka, km, kn = jax.random.split(key, 3)
    p = {
        "ln1": norm_init(cfg, cfg.d_model),
        "attn": attn_init(ka, cfg),
        "ln2": norm_init(cfg, cfg.d_model),
    }
    if cfg.num_experts > 0:
        p["moe"] = moe_mod.moe_init(km, cfg)
    else:
        p["mlp"] = mlp_init(km, cfg)
    del kn
    return p


def block_axes(cfg) -> dict:
    na = {"scale": (None,)} if cfg.norm_type != "layernorm" else {
        "scale": (None,), "bias": (None,)}
    p = {"ln1": dict(na), "attn": attn_axes(cfg), "ln2": dict(na)}
    if cfg.num_experts > 0:
        p["moe"] = moe_mod.moe_axes(cfg)
    else:
        p["mlp"] = mlp_axes(cfg)
    return p


def block_apply(params, cfg, x, *, rope, mode, cache=None, window=0):
    """Returns (x, new_cache, aux_loss) -- aux is the MoE router balance
    loss (0 for dense blocks), accumulated across layers by the trunk."""
    h, new_cache = attn_apply(
        params["attn"], cfg, norm(x, params["ln1"], cfg),
        rope=rope, causal=True, window=window, cache=cache, mode=mode)
    x = x + h
    y = norm(x, params["ln2"], cfg)
    aux = jnp.zeros((), jnp.float32)
    if cfg.num_experts > 0:
        store: list = []
        y = moe_mod.moe_apply(params["moe"], cfg, y, aux_loss_store=store)
        aux = store[0]
    else:
        y = mlp_apply(params["mlp"], cfg, y)
    return x + y, new_cache, aux


# ------------------------------ full LM -------------------------------------


def init_lm(key, cfg) -> dict:
    ke, kb, ko = jax.random.split(key, 3)
    keys = jax.random.split(kb, cfg.num_layers)
    blocks = jax.vmap(lambda k: block_init(k, cfg))(keys)
    p = {
        "embed": embed_init(ke, cfg.vocab_size, cfg.d_model),
        "blocks": blocks,
        "ln_f": norm_init(cfg, cfg.d_model),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(ko, cfg.d_model, cfg.vocab_size)
    return p


def lm_axes(cfg) -> dict:
    na = {"scale": (None,)} if cfg.norm_type != "layernorm" else {
        "scale": (None,), "bias": (None,)}
    ba = jax.tree.map(lambda ax: ("layers",) + ax, block_axes(cfg),
                      is_leaf=lambda x: isinstance(x, tuple))
    p = {"embed": ("vocab", "embed"), "blocks": ba, "ln_f": dict(na)}
    if not cfg.tie_embeddings:
        p["lm_head"] = ("embed", "vocab")
    return p


def _rope_for(cfg, positions):
    return rope_angles(positions, cfg.head_dim, cfg.rope_theta)


def _maybe_remat(fn, cfg):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


def _trunk(params, cfg, x, rope, mode, caches, window_for):
    """Scan (or unroll) the block stack.  caches: stacked over layers.
    Returns (x, new_caches, aux_loss_sum)."""
    if getattr(cfg, "cast_params_pre_scan", False):
        # §Perf knob: cast the (sharded) fp32 param stack to compute dtype
        # BEFORE the scan, so FSDP all-gathers inside the loop move bf16 --
        # the baseline gathers fp32 and converts after (2x link traffic).
        ct = dtype_of(cfg.compute_dtype)
        params = dict(params)
        params["blocks"] = jax.tree.map(
            lambda a: a.astype(ct) if a.dtype == jnp.float32 else a,
            params["blocks"])
    if cfg.scan_layers and not cfg.layer_pattern:
        def body(carry, xs):
            y, aux_sum = carry
            blk, cache_l = xs
            y, nc, aux = block_apply(blk, cfg, y, rope=rope, mode=mode,
                                     cache=cache_l, window=window_for(0))
            return (y, aux_sum + aux), nc
        body = _maybe_remat(body, cfg)
        (x, aux_sum), new_caches = jax.lax.scan(
            body, (x, jnp.zeros((), jnp.float32)), (params["blocks"], caches))
        return x, new_caches, aux_sum
    # Unrolled path (heterogeneous patterns handled by the family modules).
    new_caches = []
    aux_sum = jnp.zeros((), jnp.float32)
    for i in range(cfg.num_layers):
        blk = jax.tree.map(lambda a: a[i], params["blocks"])
        cache_l = None if caches is None else jax.tree.map(lambda a: a[i], caches)
        fn = _maybe_remat(
            lambda b, xx, cc: block_apply(b, cfg, xx, rope=rope, mode=mode,
                                          cache=cc, window=window_for(i)), cfg)
        x, nc, aux = fn(blk, x, cache_l)
        aux_sum = aux_sum + aux
        new_caches.append(nc)
    if new_caches[0] is not None:
        new_caches = jax.tree.map(lambda *ls: jnp.stack(ls), *new_caches)
    else:
        new_caches = None
    return x, new_caches, aux_sum


def apply_lm(
    params: dict,
    cfg,
    tokens: jax.Array,
    *,
    mode: str = "train",
    caches: dict | None = None,
    positions: jax.Array | None = None,
    prefix_embeds: jax.Array | None = None,
    rope_override=None,
) -> tuple[jax.Array, dict | None]:
    """tokens: (b, t) int32.  prefix_embeds: (b, tp, d) modality stub
    (VLM patches / audio frames) prepended to the token embeddings.
    Returns (logits (b, t_total, vocab), new_caches)."""
    ct = dtype_of(cfg.compute_dtype)
    x = params["embed"].astype(ct)[tokens]
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(ct), x], axis=1)
    b, t, _ = x.shape
    x = wsc(x, BATCH, None, None)

    if positions is None:
        offset = caches["len"][0] if (mode == "decode" and caches is not None) else 0
        positions = default_positions(b, t, offset)
    rope = rope_override if rope_override is not None else _rope_for(cfg, positions)

    window_for = lambda i: cfg.attention_window
    x, new_caches, aux = _trunk(params, cfg, x, rope, mode, caches, window_for)

    x = norm(x, params["ln_f"], cfg)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head.astype(ct)
    logits = softcap(logits, cfg.logit_softcap)
    logits = wsc(logits, BATCH, None, "model")
    if mode == "train":
        return logits, {"aux_loss": aux}
    return logits, new_caches


def init_caches(cfg, batch: int, s_max: int, dtype=jnp.bfloat16) -> dict:
    """Stacked-over-layers KV cache ShapeDtypeStructs (fill with zeros for
    real use; launch/dryrun uses the structs directly)."""
    one = attn_cache_spec(cfg, batch, s_max, dtype)
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((cfg.num_layers, *s.shape), s.dtype), one)


def zeros_caches(cfg, batch: int, s_max: int, dtype=jnp.bfloat16) -> dict:
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        init_caches(cfg, batch, s_max, dtype))
