"""xLSTM blocks (arXiv:2405.04517): sLSTM (scalar memory, true recurrence)
and mLSTM (matrix memory, chunkwise-parallel trainable).

The causal conv1d inside both blocks uses the paper's kn2row 1-D
decomposition (``repro.core.kn2row``) -- the direct application of the
reproduced paper's algorithm to this architecture (DESIGN.md
§Arch-applicability).

mLSTM state:  C in R^{dh x dh}, n in R^{dh}, m (log-stabilizer) per head.
  C_t = f_t C_{t-1} + i_t v_t k_t^T,  n_t = f_t n_{t-1} + i_t k_t,
  h_t = (C_t q_t) / max(|n_t . q_t|, 1)   with exp-gating stabilized by m.
Chunkwise form: within a chunk of W steps the contribution is an
attention-like matrix with decay D_{ts} = exp(F_t - F_s + logi_s); across
chunks the (C, n, m) state carries.  ``mlstm_chunkwise`` == ``mlstm_recurrent``
to numerical precision (tests/test_xlstm.py).

sLSTM is sequential by construction (h_{t-1} feeds the gates through
block-diagonal recurrent matrices R); it runs as a lax.scan over time.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.kn2row import conv1d_depthwise_causal
from .common import BATCH, dense_init, dtype_of, embed_init, norm, norm_init, wsc

# --------------------------------- mLSTM cell -------------------------------


def mlstm_recurrent(q, k, v, i_pre, f_pre, state=None):
    """Exact recurrence (reference + decode path).

    q,k,v: (b, h, t, dh); i_pre,f_pre: (b, h, t) gate pre-activations.
    state: optional (C (b,h,dh,dh), n (b,h,dh), m (b,h)) scaled by exp(-m).
    Returns (out (b,h,t,dh), final_state)."""
    b, h, t, dh = q.shape
    k = k * (dh ** -0.5)
    if state is None:
        C0 = jnp.zeros((b, h, dh, dh), jnp.float32)
        n0 = jnp.zeros((b, h, dh), jnp.float32)
        m0 = jnp.full((b, h), -jnp.inf, jnp.float32)
    else:
        C0, n0, m0 = state

    def step(carry, xs):
        C, n, m = carry
        qt, kt, vt, it, ft = xs
        m_new = jnp.maximum(ft + m, it)
        i_s = jnp.exp(it - m_new)
        f_s = jnp.exp(ft + m - m_new)
        C = f_s[..., None, None] * C + i_s[..., None, None] * (
            vt[..., :, None] * kt[..., None, :])
        n = f_s[..., None] * n + i_s[..., None] * kt
        num = jnp.einsum("bhij,bhj->bhi", C, qt)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhj,bhj->bh", n, qt)),
                          jnp.exp(-m_new))
        return (C, n, m_new), num / den[..., None]

    xs = tuple(a.transpose(2, 0, 1, 3) for a in (q, k, v)) + tuple(
        a.transpose(2, 0, 1) for a in (i_pre, f_pre))
    (Cf, nf, mf), out = jax.lax.scan(step, (C0, n0, m0), xs)
    return out.transpose(1, 2, 0, 3), (Cf, nf, mf)


def mlstm_chunkwise(q, k, v, i_pre, f_pre, state=None, chunk: int = 64):
    """Chunkwise-parallel mLSTM: intra-chunk attention-with-decay + carried
    inter-chunk state.  Exact (same stabilized math as the recurrence)."""
    b, h, t, dh = q.shape
    k = k * (dh ** -0.5)
    W = min(chunk, t)
    pad = (-t) % W
    if pad:
        z4 = lambda a: jnp.pad(a, ((0, 0), (0, 0), (0, pad), (0, 0)))
        z3 = lambda a: jnp.pad(a, ((0, 0), (0, 0), (0, pad)))
        q, k, v = z4(q), z4(k), z4(v)
        # Padded steps: i = -inf (no input), f = 0 (keep state).
        i_pre = jnp.pad(i_pre, ((0, 0), (0, 0), (0, pad)), constant_values=-1e30)
        f_pre = z3(f_pre)
    tp = t + pad
    nc = tp // W

    qc = q.reshape(b, h, nc, W, dh).transpose(2, 0, 1, 3, 4).astype(jnp.float32)
    kc = k.reshape(b, h, nc, W, dh).transpose(2, 0, 1, 3, 4).astype(jnp.float32)
    vc = v.reshape(b, h, nc, W, dh).transpose(2, 0, 1, 3, 4).astype(jnp.float32)
    ic = i_pre.reshape(b, h, nc, W).transpose(2, 0, 1, 3).astype(jnp.float32)
    fc = f_pre.reshape(b, h, nc, W).transpose(2, 0, 1, 3).astype(jnp.float32)

    if state is None:
        C0 = jnp.zeros((b, h, dh, dh), jnp.float32)
        n0 = jnp.zeros((b, h, dh), jnp.float32)
        m0 = jnp.full((b, h), -jnp.inf, jnp.float32)
    else:
        C0, n0, m0 = state

    tri = jnp.tril(jnp.ones((W, W), bool))

    def chunk_step(carry, xs):
        C, n, m_in = carry
        qt, kt, vt, it, ft = xs               # (b,h,W,[dh])
        F = jnp.cumsum(ft, axis=-1)           # (b,h,W) cumulative log-decay
        # Intra-chunk log weights: D[t,s] = F_t - F_s + i_s  for s <= t.
        D = F[..., :, None] - F[..., None, :] + it[..., None, :]
        D = jnp.where(tri, D, -jnp.inf)
        m_intra = D.max(axis=-1)              # (b,h,W)
        m_comb = jnp.maximum(F + m_in[..., None], m_intra)
        m_comb = jnp.maximum(m_comb, -1e30)   # avoid inf-inf when everything is empty
        # Intra contribution.
        logits = jnp.einsum("bhtd,bhsd->bhts", qt, kt)
        S = logits * jnp.exp(D - m_comb[..., None])
        num = jnp.einsum("bhts,bhsd->bhtd", S, vt)
        den = S.sum(axis=-1)
        # Inter (carried state) contribution.  C layout: [v-dim, k-dim];
        # contract q against the k-dim (as num = C q in the recurrence).
        inter_scale = jnp.exp(F + m_in[..., None] - m_comb)   # (b,h,W)
        num = num + jnp.einsum("bhte,bhde->bhtd", qt, C) * inter_scale[..., None]
        den = den + jnp.einsum("bhtd,bhd->bht", qt, n) * inter_scale
        out = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_comb))[..., None]
        # State update to end-of-chunk.
        F_tot = F[..., -1:]                                   # (b,h,1)
        m_state = jnp.maximum(F_tot[..., 0] + m_in,
                              (F_tot - F + it).max(axis=-1))
        decay_state = jnp.exp(F_tot[..., 0] + m_in - m_state)
        w_s = jnp.exp(F_tot - F + it - m_state[..., None])    # (b,h,W)
        C_new = decay_state[..., None, None] * C + jnp.einsum(
            "bhs,bhsd,bhse->bhde", w_s, vt, kt)
        n_new = decay_state[..., None] * n + jnp.einsum("bhs,bhsd->bhd", w_s, kt)
        return (C_new, n_new, m_state), out

    (Cf, nf, mf), outs = jax.lax.scan(chunk_step, (C0, n0, m0), (qc, kc, vc, ic, fc))
    out = outs.transpose(1, 2, 0, 3, 4).reshape(b, h, tp, dh)[:, :, :t]
    return out, (Cf, nf, mf)


# ------------------------------- mLSTM block --------------------------------


def mlstm_block_init(key, cfg) -> dict:
    d = cfg.d_model
    di = int(d * cfg.mlstm_proj_factor)
    h = cfg.num_heads
    ks = jax.random.split(key, 8)
    return {
        "ln": norm_init(cfg, d),
        "w_up_x": dense_init(ks[0], d, di),
        "w_up_z": dense_init(ks[1], d, di),
        "conv_w": (jax.random.normal(ks[2], (cfg.conv_width, di)) * 0.1).astype(jnp.float32),
        "wq": dense_init(ks[3], di, di),
        "wk": dense_init(ks[4], di, di),
        "wv": dense_init(ks[5], di, di),
        "w_i": dense_init(ks[6], di, h),
        "b_i": jnp.zeros((h,), jnp.float32),
        "w_f": dense_init(ks[7], di, h),
        "b_f": jnp.full((h,), 3.0, jnp.float32),   # forget-gate bias: start remembering
        "gn": jnp.zeros((di,), jnp.float32),
        "w_down": dense_init(jax.random.fold_in(key, 9), di, d),
    }


def mlstm_block_axes(cfg) -> dict:
    return {
        "ln": {"scale": (None,)},
        "w_up_x": ("embed", "mlp"), "w_up_z": ("embed", "mlp"),
        "conv_w": (None, "mlp"),
        "wq": ("mlp", "mlp2"), "wk": ("mlp", "mlp2"), "wv": ("mlp", "mlp2"),
        "w_i": ("mlp", None), "b_i": (None,),
        "w_f": ("mlp", None), "b_f": (None,),
        "gn": ("mlp",),
        "w_down": ("mlp", "embed"),
    }


def _groupnorm_heads(x, scale, heads: int, eps=1e-5):
    """GroupNorm over head groups: x (b, t, di)."""
    b, t, di = x.shape
    xh = x.astype(jnp.float32).reshape(b, t, heads, di // heads)
    mu = xh.mean(-1, keepdims=True)
    var = xh.var(-1, keepdims=True)
    xh = (xh - mu) * jax.lax.rsqrt(var + eps)
    return (xh.reshape(b, t, di) * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def mlstm_block_apply(params, cfg, x, *, mode="train", cache=None):
    """x: (b, t, d). cache (decode): {C, n, m, conv} rolling state."""
    b, t, d = x.shape
    ct = x.dtype
    h = cfg.num_heads
    di = params["w_up_x"].shape[1]
    dh = di // h

    y = norm(x, params["ln"], cfg)
    x_in = y @ params["w_up_x"].astype(ct)
    z = y @ params["w_up_z"].astype(ct)

    # Causal depthwise conv -- the paper's kn2row-1D path.
    if mode == "decode":
        conv_buf = cache["conv"]  # (b, w-1, di): previous inputs
        seq = jnp.concatenate([conv_buf.astype(ct), x_in], axis=1)
        x_conv = conv1d_depthwise_causal(seq, params["conv_w"].astype(ct))[:, -t:]
        new_conv = seq[:, -(cfg.conv_width - 1):]
    else:
        x_conv = conv1d_depthwise_causal(x_in, params["conv_w"].astype(ct))
        new_conv = x_in[:, -(cfg.conv_width - 1):]
    x_conv = jax.nn.silu(x_conv)

    def heads_split(a):
        return a.reshape(b, t, h, dh).transpose(0, 2, 1, 3)

    q = heads_split(x_conv @ params["wq"].astype(ct)).astype(jnp.float32)
    k = heads_split(x_conv @ params["wk"].astype(ct)).astype(jnp.float32)
    v = heads_split(x_in @ params["wv"].astype(ct)).astype(jnp.float32)
    i_pre = (x_conv @ params["w_i"].astype(ct) + params["b_i"].astype(ct)) \
        .astype(jnp.float32).transpose(0, 2, 1)
    f_pre = jax.nn.log_sigmoid(
        (x_conv @ params["w_f"].astype(ct) + params["b_f"].astype(ct))
        .astype(jnp.float32)).transpose(0, 2, 1)

    state = None
    if mode == "decode":
        state = (cache["C"], cache["n"], cache["m"])
    if mode == "decode" or t <= cfg.mlstm_chunk:
        out, new_state = mlstm_recurrent(q, k, v, i_pre, f_pre, state)
    else:
        out, new_state = mlstm_chunkwise(q, k, v, i_pre, f_pre, state,
                                         chunk=cfg.mlstm_chunk)

    out = out.transpose(0, 2, 1, 3).reshape(b, t, di).astype(ct)
    out = _groupnorm_heads(out, params["gn"], h)
    out = out * jax.nn.silu(z)
    out = out @ params["w_down"].astype(ct)

    new_cache = None
    if mode in ("prefill", "decode"):
        C, n, m = new_state
        new_cache = {"C": C, "n": n, "m": m, "conv": new_conv.astype(ct)}
    return x + wsc(out, BATCH, None, None), new_cache


def mlstm_cache_spec(cfg, batch: int) -> dict:
    di = int(cfg.d_model * cfg.mlstm_proj_factor)
    h = cfg.num_heads
    dh = di // h
    return {
        "C": jax.ShapeDtypeStruct((batch, h, dh, dh), jnp.float32),
        "n": jax.ShapeDtypeStruct((batch, h, dh), jnp.float32),
        "m": jax.ShapeDtypeStruct((batch, h), jnp.float32),
        "conv": jax.ShapeDtypeStruct((batch, cfg.conv_width - 1, di),
                                     dtype_of(cfg.compute_dtype)),
    }


# ------------------------------- sLSTM block --------------------------------


def slstm_block_init(key, cfg) -> dict:
    d = cfg.d_model
    h = cfg.num_heads
    dh = d // h
    ks = jax.random.split(key, 12)
    gate_w = lambda kk: dense_init(kk, d, d)
    rec_w = lambda kk: (jax.random.normal(kk, (h, dh, dh)) * (dh ** -0.5)).astype(jnp.float32)
    dff = int(d * cfg.slstm_proj_factor)
    return {
        "ln": norm_init(cfg, d),
        "conv_w": (jax.random.normal(ks[0], (cfg.conv_width, d)) * 0.1).astype(jnp.float32),
        "wz": gate_w(ks[1]), "wi": gate_w(ks[2]), "wf": gate_w(ks[3]), "wo": gate_w(ks[4]),
        "rz": rec_w(ks[5]), "ri": rec_w(ks[6]), "rf": rec_w(ks[7]), "ro": rec_w(ks[8]),
        "bz": jnp.zeros((d,), jnp.float32), "bi": jnp.zeros((d,), jnp.float32),
        "bf": jnp.full((d,), 3.0, jnp.float32), "bo": jnp.zeros((d,), jnp.float32),
        "gn": jnp.zeros((d,), jnp.float32),
        "w_up1": dense_init(ks[9], d, dff),
        "w_up2": dense_init(ks[10], d, dff),
        "w_down": dense_init(ks[11], dff, d),
    }


def slstm_block_axes(cfg) -> dict:
    return {
        "ln": {"scale": (None,)},
        "conv_w": (None, "embed"),
        "wz": ("embed", "embed2"), "wi": ("embed", "embed2"),
        "wf": ("embed", "embed2"), "wo": ("embed", "embed2"),
        "rz": ("heads", None, None), "ri": ("heads", None, None),
        "rf": ("heads", None, None), "ro": ("heads", None, None),
        "bz": (None,), "bi": (None,), "bf": (None,), "bo": (None,),
        "gn": ("embed",),
        "w_up1": ("embed", "mlp"), "w_up2": ("embed", "mlp"),
        "w_down": ("mlp", "embed"),
    }


def slstm_block_apply(params, cfg, x, *, mode="train", cache=None):
    b, t, d = x.shape
    ct = x.dtype
    h = cfg.num_heads
    dh = d // h

    y = norm(x, params["ln"], cfg)
    if mode == "decode":
        seq = jnp.concatenate([cache["conv"].astype(ct), y], axis=1)
        y_conv = conv1d_depthwise_causal(seq, params["conv_w"].astype(ct))[:, -t:]
        new_conv = seq[:, -(cfg.conv_width - 1):]
    else:
        y_conv = conv1d_depthwise_causal(y, params["conv_w"].astype(ct))
        new_conv = y[:, -(cfg.conv_width - 1):]
    y_conv = jax.nn.silu(y_conv)

    # Gate input projections (i, f use the conv path -- xLSTM paper).
    gz = (y @ params["wz"].astype(ct) + params["bz"].astype(ct)).astype(jnp.float32)
    go = (y @ params["wo"].astype(ct) + params["bo"].astype(ct)).astype(jnp.float32)
    gi = (y_conv @ params["wi"].astype(ct) + params["bi"].astype(ct)).astype(jnp.float32)
    gf = (y_conv @ params["wf"].astype(ct) + params["bf"].astype(ct)).astype(jnp.float32)

    def heads_view(a):  # (b, t, d) -> (t, b, h, dh)
        return a.reshape(b, t, h, dh).transpose(1, 0, 2, 3)

    if mode == "decode" and cache is not None:
        carry0 = (cache["c"], cache["n"], cache["m"], cache["h"])
    else:
        z0 = jnp.zeros((b, h, dh), jnp.float32)
        carry0 = (z0, z0, jnp.full((b, h, dh), -jnp.inf, jnp.float32), z0)

    def step(carry, xs):
        c, n, m, h_prev = carry
        zt, it, ft, ot = xs
        rec = lambda w: jnp.einsum("bhj,hjk->bhk", h_prev, w)
        zt = jnp.tanh(zt + rec(params["rz"]))
        ot = jax.nn.sigmoid(ot + rec(params["ro"]))
        it = it + rec(params["ri"])
        ft = jax.nn.log_sigmoid(ft + rec(params["rf"]))
        m_new = jnp.maximum(ft + m, it)
        i_s = jnp.exp(it - m_new)
        f_s = jnp.exp(ft + m - m_new)
        c_new = f_s * c + i_s * zt
        n_new = f_s * n + i_s
        h_new = ot * c_new / jnp.maximum(n_new, 1e-6)
        return (c_new, n_new, m_new, h_new), h_new

    xs = tuple(heads_view(a) for a in (gz, gi, gf, go))
    (cf, nf, mf, hf), hs = jax.lax.scan(step, carry0, xs)
    out = hs.transpose(1, 0, 2, 3).reshape(b, t, d).astype(ct)
    out = _groupnorm_heads(out, params["gn"], h)
    # Post up/down projection (GeGLU, pf = 4/3).
    up = jax.nn.gelu(out @ params["w_up1"].astype(ct)) * (out @ params["w_up2"].astype(ct))
    out = up @ params["w_down"].astype(ct)

    new_cache = None
    if mode in ("prefill", "decode"):
        new_cache = {"c": cf, "n": nf, "m": mf, "h": hf,
                     "conv": new_conv.astype(ct)}
    return x + wsc(out, BATCH, None, None), new_cache


def slstm_cache_spec(cfg, batch: int) -> dict:
    h = cfg.num_heads
    dh = cfg.d_model // h
    s = jax.ShapeDtypeStruct((batch, h, dh), jnp.float32)
    return {"c": s, "n": s, "m": s, "h": s,
            "conv": jax.ShapeDtypeStruct(
                (batch, cfg.conv_width - 1, cfg.d_model), jnp.bfloat16)}


# --------------------------------- full LM ----------------------------------


def init_lm(key, cfg) -> dict:
    ke, kb, ko = jax.random.split(key, 3)
    pattern = cfg.pattern()
    blocks = []
    for i, kind in enumerate(pattern):
        kk = jax.random.fold_in(kb, i)
        blocks.append(mlstm_block_init(kk, cfg) if kind == "m"
                      else slstm_block_init(kk, cfg))
    p = {
        "embed": embed_init(ke, cfg.vocab_size, cfg.d_model),
        "blocks": blocks,
        "ln_f": norm_init(cfg, cfg.d_model),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(ko, cfg.d_model, cfg.vocab_size)
    return p


def lm_axes(cfg) -> dict:
    blocks = [mlstm_block_axes(cfg) if k == "m" else slstm_block_axes(cfg)
              for k in cfg.pattern()]
    p = {"embed": ("vocab", "embed"), "blocks": blocks, "ln_f": {"scale": (None,)}}
    if not cfg.tie_embeddings:
        p["lm_head"] = ("embed", "vocab")
    return p


def apply_lm(params, cfg, tokens, *, mode="train", caches=None, positions=None,
             prefix_embeds=None, rope_override=None):
    del positions, rope_override  # recurrent family: no rope
    ct = dtype_of(cfg.compute_dtype)
    x = params["embed"].astype(ct)[tokens]
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(ct), x], axis=1)
    x = wsc(x, BATCH, None, None)

    if getattr(cfg, "cast_params_pre_scan", False):
        ct2 = dtype_of(cfg.compute_dtype)
        params = dict(params)
        params["blocks"] = jax.tree.map(
            lambda a: a.astype(ct2) if a.dtype == jnp.float32 else a,
            params["blocks"])

    new_caches = []
    for i, kind in enumerate(cfg.pattern()):
        blk = params["blocks"][i]
        cache_l = None if caches is None else caches[i]
        block_fn = mlstm_block_apply if kind == "m" else slstm_block_apply
        fn = lambda p_, x_, c_, f_=block_fn: f_(p_, cfg, x_, mode=mode, cache=c_)
        if cfg.remat != "none" and mode == "train":
            fn = jax.checkpoint(fn)
        x, nc = fn(blk, x, cache_l)
        new_caches.append(nc)

    x = norm(x, params["ln_f"], cfg)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head.astype(ct)
    return wsc(logits, BATCH, None, "model"), (new_caches if mode != "train" else None)


def init_caches(cfg, batch: int, s_max: int = 0, dtype=jnp.bfloat16) -> list:
    """Per-layer recurrent state specs (list, heterogeneous pattern)."""
    del s_max, dtype  # state is O(1) in sequence length -- the ssm advantage
    return [mlstm_cache_spec(cfg, batch) if k == "m" else slstm_cache_spec(cfg, batch)
            for k in cfg.pattern()]


def zeros_caches(cfg, batch: int, s_max: int = 0) -> list:
    caches = []
    for k, spec in zip(cfg.pattern(), init_caches(cfg, batch)):
        z = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), spec)
        z["m"] = jnp.full(z["m"].shape, -1e30, jnp.float32)  # empty-state stabilizer
        caches.append(z)
    return caches
