"""Model registry: uniform functional API per architecture family.

    api = get_model(cfg)
    params = api.init(key, cfg)
    logits, _ = api.apply(params, cfg, tokens, mode="train")
    caches = api.init_caches(cfg, batch, s_max)     # specs (ShapeDtypeStruct)
    logits, caches = api.apply(..., mode="decode", caches=zeros)
"""

from __future__ import annotations

import dataclasses
from typing import Callable

from . import dense, encdec, rglru, vlm, xlstm


@dataclasses.dataclass(frozen=True)
class ModelAPI:
    init: Callable
    apply: Callable
    axes: Callable            # cfg -> pytree of logical-axis tuples
    init_caches: Callable     # cfg, batch, s_max -> cache ShapeDtypeStructs
    zeros_caches: Callable
    has_decode: bool = True


_FAMILIES = {
    "dense": ModelAPI(dense.init_lm, dense.apply_lm, dense.lm_axes,
                      dense.init_caches, dense.zeros_caches),
    "moe": ModelAPI(dense.init_lm, dense.apply_lm, dense.lm_axes,
                    dense.init_caches, dense.zeros_caches),
    "vlm": ModelAPI(dense.init_lm, vlm.apply_lm, dense.lm_axes,
                    dense.init_caches, dense.zeros_caches),
    "xlstm": ModelAPI(xlstm.init_lm, xlstm.apply_lm, xlstm.lm_axes,
                      xlstm.init_caches, xlstm.zeros_caches),
    "hybrid": ModelAPI(rglru.init_lm, rglru.apply_lm, rglru.lm_axes,
                       rglru.init_caches, rglru.zeros_caches),
    "encdec": ModelAPI(encdec.init_lm, encdec.apply_lm, encdec.lm_axes,
                       encdec.init_caches, encdec.zeros_caches),
}


def get_model(cfg) -> ModelAPI:
    try:
        return _FAMILIES[cfg.family]
    except KeyError:
        raise ValueError(f"unknown family {cfg.family!r}; "
                         f"known: {sorted(_FAMILIES)}") from None
