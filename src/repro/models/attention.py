"""Attention: GQA with chunked online-softmax (flash-style in pure JAX),
sliding-window (local) variant, cross-attention, and KV-cache decode.

Memory design: prefill at 32k tokens can NEVER materialize the full
[b, h, s, s] score tensor.  ``chunked_attention`` double-chunks (lax.map
over query blocks, lax.scan over KV blocks) carrying the online-softmax
running (max, denom, acc) so peak memory is O(q_chunk * kv_chunk).

The pure-JAX version processes all KV blocks under a mask (the causal
block-skip lives in the Pallas flash kernel -- see kernels/flash and
EXPERIMENTS.md §Perf for the measured HLO-flops delta).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .common import apply_rope, dense_init, wsc

NEG_INF = -1e30


def _mask_block(
    qpos: jax.Array, kpos: jax.Array, *, causal: bool, window: int, kv_len: jax.Array | None
) -> jax.Array:
    """(qc, kc) boolean visibility mask from absolute positions."""
    m = jnp.ones((qpos.shape[0], kpos.shape[0]), dtype=bool)
    if causal:
        m &= kpos[None, :] <= qpos[:, None]
    if window > 0:
        m &= qpos[:, None] - kpos[None, :] < window
    if kv_len is not None:
        m &= kpos[None, :] < kv_len
    return m


def _flash_fwd_blocks(qp, kp, vp, *, causal, window, q_offset, kv_valid,
                      q_chunk, kv_chunk, scale, bf16_operands=False,
                      bf16_p=False):
    """qp: (nq, b, hkv, g, qc, hd); kp/vp: (nk, b, hkv, kc, hd).
    Returns (out (nq, b, hkv, g, qc, hd), lse (nq, b, hkv, g, qc))."""
    nq = qp.shape[0]
    b, hkv, g, qc, hd = qp.shape[1:]

    def q_block(args):
        qi, qblk = args
        qpos = q_offset + qi * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, kv):
            m_run, l_run, acc = carry
            kj, kblk, vblk = kv
            kpos = kj * kv_chunk + jnp.arange(kv_chunk)
            if bf16_operands:
                # keep bf16 into the MXU; fp32 accumulate (halves HBM reads
                # of score-dot operands -- §Perf knob)
                s = jnp.einsum("bhgqd,bhkd->bhgqk", qblk, kblk,
                               preferred_element_type=jnp.float32) * scale
            else:
                s = jnp.einsum(
                    "bhgqd,bhkd->bhgqk", qblk.astype(jnp.float32),
                    kblk.astype(jnp.float32)) * scale
            mask = _mask_block(qpos, kpos, causal=causal, window=window,
                               kv_len=kv_valid)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m_run, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m_run - m_new)
            l_new = l_run * alpha + p.sum(axis=-1)
            if bf16_p:
                pv = jnp.einsum("bhgqk,bhkd->bhgqd", p.astype(jnp.bfloat16),
                                vblk.astype(jnp.bfloat16),
                                preferred_element_type=jnp.float32)
            else:
                pv = jnp.einsum("bhgqk,bhkd->bhgqd", p,
                                vblk.astype(jnp.float32))
            acc_new = acc * alpha[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, hkv, g, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, q_chunk, hd), jnp.float32)
        nk = kp.shape[0]
        (m_f, l_f, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (jnp.arange(nk), kp, vp))
        out = acc / jnp.maximum(l_f[..., None], 1e-30)
        lse = m_f + jnp.log(jnp.maximum(l_f, 1e-30))
        return out, lse

    return jax.lax.map(q_block, (jnp.arange(nq), qp))


@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(3, 4, 5, 6, 7, 8, 9, 10))
def _flash_attention(qp, kp, vp, causal, window, q_offset, kv_valid,
                     q_chunk, kv_chunk, bf16_operands=False, bf16_p=False):
    """Blocked flash attention with a flash BACKWARD (custom VJP).

    Without this, the scan/map backward materializes every fp32 score
    block -- the full [sq, skv] attention matrix (measured: 16 GiB/device
    at 4k seq on smollm train) -- exactly what flash attention exists to
    avoid.  The backward below recomputes score blocks from (q, k, v, lse)
    and accumulates dq/dk/dv blockwise."""
    scale = qp.shape[-1] ** -0.5
    out, _ = _flash_fwd_blocks(qp, kp, vp, causal=causal, window=window,
                               q_offset=q_offset, kv_valid=kv_valid,
                               q_chunk=q_chunk, kv_chunk=kv_chunk, scale=scale,
                               bf16_operands=bf16_operands, bf16_p=bf16_p)
    return out


def _flash_fwd_rule(qp, kp, vp, causal, window, q_offset, kv_valid,
                    q_chunk, kv_chunk, bf16_operands=False, bf16_p=False):
    scale = qp.shape[-1] ** -0.5
    out, lse = _flash_fwd_blocks(qp, kp, vp, causal=causal, window=window,
                                 q_offset=q_offset, kv_valid=kv_valid,
                                 q_chunk=q_chunk, kv_chunk=kv_chunk,
                                 scale=scale, bf16_operands=bf16_operands,
                                 bf16_p=bf16_p)
    return out, (qp, kp, vp, out, lse)


def _flash_bwd_rule(causal, window, q_offset, kv_valid, q_chunk, kv_chunk,
                    bf16_operands, bf16_p, res, dout):
    qp, kp, vp, out, lse = res
    scale = qp.shape[-1] ** -0.5
    nq = qp.shape[0]
    nk = kp.shape[0]
    # delta_i = rowsum(dout * out) -- the softmax-backward correction term.
    delta = jnp.sum(dout.astype(jnp.float32) * out.astype(jnp.float32), axis=-1)

    def q_step(kv_grads, xs):
        dk_acc, dv_acc = kv_grads
        qi, qblk, oblk_d, lse_i, delta_i = xs
        qpos = q_offset + qi * q_chunk + jnp.arange(q_chunk)
        qf = qblk.astype(jnp.float32)

        def kv_step(carry, kv):
            dq_i, dk_acc, dv_acc = carry
            kj, kblk, vblk = kv
            kpos = kj * kv_chunk + jnp.arange(kv_chunk)
            kf, vf = kblk.astype(jnp.float32), vblk.astype(jnp.float32)
            if bf16_operands:
                s = jnp.einsum("bhgqd,bhkd->bhgqk", qblk, kblk,
                               preferred_element_type=jnp.float32) * scale
            else:
                s = jnp.einsum("bhgqd,bhkd->bhgqk", qf, kf) * scale
            mask = _mask_block(qpos, kpos, causal=causal, window=window,
                               kv_len=kv_valid)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            p = jnp.exp(s - lse_i[..., None])                      # recomputed
            do = oblk_d.astype(jnp.float32)
            dv = jnp.einsum("bhgqk,bhgqd->bhkd", p, do)
            dp = jnp.einsum("bhgqd,bhkd->bhgqk", do, vf)
            ds = p * (dp - delta_i[..., None]) * scale
            dq_i = dq_i + jnp.einsum("bhgqk,bhkd->bhgqd", ds, kf)
            dk = jnp.einsum("bhgqk,bhgqd->bhkd", ds, qf)
            dk_acc = dk_acc.at[kj].add(dk)
            dv_acc = dv_acc.at[kj].add(dv)
            return (dq_i, dk_acc, dv_acc), None

        dq0 = jnp.zeros(qblk.shape, jnp.float32)
        (dq_i, dk_acc, dv_acc), _ = jax.lax.scan(
            kv_step, (dq0, dk_acc, dv_acc), (jnp.arange(nk), kp, vp))
        return (dk_acc, dv_acc), dq_i

    dk0 = jnp.zeros((nk, *kp.shape[1:]), jnp.float32)
    dv0 = jnp.zeros((nk, *vp.shape[1:]), jnp.float32)
    (dk, dv), dq = jax.lax.scan(
        q_step, (dk0, dv0),
        (jnp.arange(nq), qp, dout, lse, delta))
    return dq.astype(qp.dtype), dk.astype(kp.dtype), dv.astype(vp.dtype)


_flash_attention.defvjp(_flash_fwd_rule, _flash_bwd_rule)


def chunked_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: jax.Array | int = 0,
    kv_len: jax.Array | None = None,
    q_chunk: int = 512,
    kv_chunk: int = 1024,
    bf16_operands: bool = False,
    bf16_p: bool = False,
) -> jax.Array:
    """q: (b, hq, sq, d); k/v: (b, hkv, skv, d); GQA via hq = g * hkv.

    Returns (b, hq, sq, d).  Flash forward + flash backward (custom VJP);
    fp32 accumulation, bf16-safe inputs."""
    b, hq, sq, hd = q.shape
    _, hkv, skv, _ = k.shape
    g = hq // hkv

    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, skv)
    nq = -(-sq // q_chunk)
    nk = -(-skv // kv_chunk)
    # Pad seq dims to multiples of the chunk (mask handles the tail).
    sq_p, skv_p = nq * q_chunk, nk * kv_chunk
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, sq_p - sq), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, skv_p - skv), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, skv_p - skv), (0, 0)))
    kv_valid = jnp.asarray(skv if kv_len is None else kv_len, jnp.int32)

    qp = qp.reshape(b, hkv, g, nq, q_chunk, hd).transpose(3, 0, 1, 2, 4, 5)
    kp = kp.reshape(b, hkv, nk, kv_chunk, hd).transpose(2, 0, 1, 3, 4)
    vp = vp.reshape(b, hkv, nk, kv_chunk, hd).transpose(2, 0, 1, 3, 4)

    if kv_len is None:
        # Static valid-length: the custom-VJP flash path (train/prefill).
        outs = _flash_attention(qp, kp, vp, causal, window, q_offset,
                                int(skv), q_chunk, kv_chunk,
                                bf16_operands, bf16_p)
    else:
        # Dynamic cache length (no gradient flows here): plain blocked fwd.
        outs, _ = _flash_fwd_blocks(
            qp, kp, vp, causal=causal, window=window, q_offset=q_offset,
            kv_valid=kv_valid, q_chunk=q_chunk, kv_chunk=kv_chunk,
            scale=hd ** -0.5)
    out = outs.transpose(1, 2, 3, 0, 4, 5).reshape(b, hq, sq_p, hd)[:, :, :sq]
    return out.astype(q.dtype)


def decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    cache_len: jax.Array,
    *,
    window: int = 0,
) -> jax.Array:
    """Single-position attention against the cache.

    q: (b, hq, 1, d); caches: (b, hkv, S, d); cache_len: () current length
    (the new token's position is cache_len - 1 after insertion)."""
    b, hq, sq, hd = q.shape
    _, hkv, S, _ = k_cache.shape
    g = hq // hkv
    qf = q.reshape(b, hkv, g, sq, hd).astype(jnp.float32)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qf, k_cache.astype(jnp.float32)) * (hd ** -0.5)
    kpos = jnp.arange(S)
    qpos = cache_len - 1
    mask = kpos[None, :] <= qpos  # causal vs cache
    if window > 0:
        mask &= qpos - kpos[None, :] < window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, hq, sq, hd).astype(q.dtype)


# --------------------------- attention block --------------------------------


def attn_init(key, cfg, *, cross: bool = False) -> dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    p = {
        "wq": dense_init(k1, d, qd),
        "wk": dense_init(k2, d, kvd),
        "wv": dense_init(k3, d, kvd),
        "wo": dense_init(k4, qd, d),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((qd,), jnp.float32)
        p["bk"] = jnp.zeros((kvd,), jnp.float32)
        p["bv"] = jnp.zeros((kvd,), jnp.float32)
    return p


def attn_axes(cfg, *, cross: bool = False) -> dict:
    """Logical axes per leaf (see dist/sharding.py for the mesh mapping)."""
    p = {
        "wq": ("embed", "q_heads"),
        "wk": ("embed", "kv_heads"),
        "wv": ("embed", "kv_heads"),
        "wo": ("q_heads", "embed"),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = ("q_heads",)
        p["bk"] = ("kv_heads",)
        p["bv"] = ("kv_heads",)
    return p


def _project_qkv(params, cfg, x, kv_x):
    b, t, _ = x.shape
    ct = x.dtype
    q = x @ params["wq"].astype(ct)
    k = kv_x @ params["wk"].astype(ct)
    v = kv_x @ params["wv"].astype(ct)
    if "bq" in params:
        q = q + params["bq"].astype(ct)
        k = k + params["bk"].astype(ct)
        v = v + params["bv"].astype(ct)
    q = q.reshape(b, t, cfg.num_heads, cfg.head_dim)
    k = k.reshape(b, kv_x.shape[1], cfg.num_kv_heads, cfg.head_dim)
    v = v.reshape(b, kv_x.shape[1], cfg.num_kv_heads, cfg.head_dim)
    return q, k, v


def attn_apply(
    params: dict,
    cfg,
    x: jax.Array,
    *,
    rope: tuple[jax.Array, jax.Array] | None,
    causal: bool = True,
    window: int = 0,
    kv_x: jax.Array | None = None,
    cache: dict | None = None,
    mode: str = "train",
) -> tuple[jax.Array, dict | None]:
    """One attention block.  mode: 'train' | 'prefill' | 'decode'.

    'prefill' fills and returns a cache of capacity cfg.max_target_len;
    'decode' consumes x of seq-len 1 plus the cache and appends to it.
    Cross-attention (kv_x = encoder output) caches K/V once at prefill."""
    b, t, _ = x.shape
    cross = kv_x is not None
    q, k, v = _project_qkv(params, cfg, x, kv_x if cross else x)

    if rope is not None and not cross:
        cos, sin = rope
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)

    q = q.transpose(0, 2, 1, 3)  # (b, h, t, d)
    k = k.transpose(0, 2, 1, 3)
    v = v.transpose(0, 2, 1, 3)
    pad_h = 0
    if (getattr(cfg, "attn_pad_heads", False)
            and cfg.num_heads == cfg.num_kv_heads
            and mode in ("train", "prefill")):
        from .common import mesh_axis_names
        m = jax.sharding.get_abstract_mesh()
        if not m.empty and "model" in m.axis_names:
            ms = dict(zip(m.axis_names, m.axis_sizes))["model"]
            target = -(-cfg.num_heads // ms) * ms
            pad_h = target - cfg.num_heads
            if pad_h:
                padded = ((0, 0), (0, pad_h), (0, 0), (0, 0))
                q, k, v = (jnp.pad(a, padded) for a in (q, k, v))
    if getattr(cfg, "attn_batch_shard", False):
        # §Perf knob: reshard batch over (pod, data, model) for the
        # attention compute -- archs whose heads do not divide the model
        # axis (smollm: 15 heads) otherwise run attention fully replicated
        # across it.  Cheap all-to-all of q/k/v/out vs model-axis-x compute.
        full = (("pod", "data", "model"),)
        q = wsc(q, full[0], None, None, None)
        k = wsc(k, full[0], None, None, None)
        v = wsc(v, full[0], None, None, None)
    else:
        q = wsc(q, ("pod", "data"), "model", None, None)
        k = wsc(k, ("pod", "data"), "model", None, None)
        v = wsc(v, ("pod", "data"), "model", None, None)
    attn_kw = dict(
        q_chunk=getattr(cfg, "flash_q_chunk", 512),
        kv_chunk=getattr(cfg, "flash_kv_chunk", 1024),
        bf16_operands=getattr(cfg, "flash_bf16_operands", False),
        bf16_p=getattr(cfg, "flash_bf16_p", False))

    new_cache = None
    if mode == "train":
        out = chunked_attention(q, k, v, causal=causal and not cross,
                                window=window, **attn_kw)
    elif mode == "prefill":
        out = chunked_attention(q, k, v, causal=causal and not cross,
                                window=window, **attn_kw)
        k_store = k[:, : cfg.num_kv_heads]  # unpadded heads into the cache
        v_store = v[:, : cfg.num_kv_heads]
        S = k_store.shape[2] if cross else cfg.max_target_len
        kc = jnp.zeros((b, cfg.num_kv_heads, S, cfg.head_dim), k.dtype)
        vc = jnp.zeros_like(kc)
        kc = jax.lax.dynamic_update_slice(kc, k_store, (0, 0, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, v_store, (0, 0, 0, 0))
        # Cache length: decoder tokens written (self) / encoder length (cross).
        new_cache = {"k": kc, "v": vc,
                     "len": jnp.asarray(k.shape[2] if cross else t, jnp.int32)}
    elif mode == "decode":
        assert cache is not None
        if cross:
            # K/V fixed from prefill; just attend.
            out = decode_attention(q, cache["k"], cache["v"], cache["len"],
                                   window=0)
            new_cache = cache
        else:
            pos = cache["len"]
            kc = jax.lax.dynamic_update_slice(cache["k"], k, (0, 0, pos, 0))
            vc = jax.lax.dynamic_update_slice(cache["v"], v, (0, 0, pos, 0))
            new_len = pos + t
            out = decode_attention(q, kc, vc, new_len, window=window)
            new_cache = {"k": kc, "v": vc, "len": new_len}
    else:
        raise ValueError(mode)

    if pad_h:
        out = out[:, : cfg.num_heads]
    out = out.transpose(0, 2, 1, 3).reshape(b, t, cfg.q_dim)
    out = out @ params["wo"].astype(out.dtype)
    return wsc(out, ("pod", "data"), None, None), new_cache


def attn_cache_spec(cfg, batch: int, s_max: int, dtype=jnp.bfloat16) -> dict:
    """ShapeDtypeStruct cache skeleton for one layer (self-attention)."""
    shp = (batch, cfg.num_kv_heads, s_max, cfg.head_dim)
    return {
        "k": jax.ShapeDtypeStruct(shp, dtype),
        "v": jax.ShapeDtypeStruct(shp, dtype),
        "len": jax.ShapeDtypeStruct((), jnp.int32),
    }
