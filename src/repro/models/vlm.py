"""Qwen2-VL backbone (arXiv:2409.12191): the decoder LM trunk with
M-RoPE (multimodal rotary sections for temporal/height/width position
streams).  The vision tower is a STUB per the brief: ``input_specs()``
feeds precomputed patch embeddings, which the trunk consumes as a prefix
ahead of the text tokens.  The patch-embedding conv itself is expressible
as the reproduced paper's kn2row 1x1 GEMM (see core.kn2row), exercised in
examples/.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import dense
from .common import default_positions, mrope_angles

init_lm = dense.init_lm
lm_axes = dense.lm_axes
init_caches = dense.init_caches
zeros_caches = dense.zeros_caches


def mrope_positions(b: int, num_patches: int, t_text: int,
                    grid: tuple[int, int] | None = None) -> jax.Array:
    """Default M-RoPE position ids (3, b, t_total): vision patches get
    (t=0, h=row, w=col); text tokens advance all three streams together
    starting after the vision extent (the Qwen2-VL scheme)."""
    if grid is None:
        side = max(int(num_patches ** 0.5), 1)
        grid = (side, max(1, -(-num_patches // side)))
    gh, gw = grid
    rows = (jnp.arange(num_patches) // gw).astype(jnp.int32)
    cols = (jnp.arange(num_patches) % gw).astype(jnp.int32)
    tpos = jnp.zeros((num_patches,), jnp.int32)
    start = int(max(gh, gw))
    text = jnp.arange(t_text, dtype=jnp.int32) + start
    three = jnp.stack([
        jnp.concatenate([tpos, text]),
        jnp.concatenate([rows, text]),
        jnp.concatenate([cols, text]),
    ])  # (3, t_total)
    return jnp.broadcast_to(three[:, None, :], (3, b, num_patches + t_text))


def apply_lm(params, cfg, tokens, *, mode="train", caches=None, positions=None,
             prefix_embeds=None, rope_override=None):
    """positions: (3, b, t_total) M-RoPE ids; derived when omitted."""
    b = tokens.shape[0]
    t_text = tokens.shape[1]
    npatch = prefix_embeds.shape[1] if prefix_embeds is not None else 0

    if rope_override is None:
        if positions is None:
            if mode == "decode" and caches is not None:
                # Continue the M-RoPE *text* stream: text positions start at
                # max(grid) after the vision prefix, so the next position is
                # start + text_len = start + (cache_len - num_patches).
                np_pref = cfg.num_patches
                side = max(int(np_pref ** 0.5), 1)
                gw = max(1, -(-np_pref // side))
                start = int(max(side, gw)) if np_pref > 0 else 0
                off = caches["len"][0] - np_pref + start
                pos1 = (jnp.zeros((b, t_text), jnp.int32) + off
                        + jnp.arange(t_text, dtype=jnp.int32)[None])
                positions = jnp.broadcast_to(pos1[None], (3, b, t_text))
            else:
                positions = mrope_positions(b, npatch, t_text)
        rope_override = mrope_angles(positions, cfg.mrope_sections,
                                     cfg.head_dim, cfg.rope_theta)

    return dense.apply_lm(params, cfg, tokens, mode=mode, caches=caches,
                          positions=None, prefix_embeds=prefix_embeds,
                          rope_override=rope_override)
