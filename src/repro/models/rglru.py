"""RecurrentGemma / Griffin hybrid (arXiv:2402.19427): RG-LRU recurrent
blocks + local (sliding-window) attention in a 1:2 attention:recurrent
pattern, GeGLU MLPs.

RG-LRU:  r_t = sigmoid(W_a x_t + b_a)        (recurrence gate)
         i_t = sigmoid(W_x x_t + b_x)        (input gate)
         a_t = exp(-c * softplus(Lambda) * r_t),   c = 8
         h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

The diagonal linear recurrence runs as a jax.lax.associative_scan
(log-depth, TPU-friendly); decode carries h as O(1) state.  The causal
depthwise conv ahead of the LRU uses the reproduced paper's kn2row-1D
decomposition.  Attention layers keep a ROTATING window KV cache
(capacity = window), so long_500k decode memory is O(window), not O(t).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.kn2row import conv1d_depthwise_causal
from .common import (
    BATCH, default_positions, dense_init, dtype_of, embed_init, norm,
    norm_init, rope_angles, softcap, wsc,
)
from .attention import attn_apply, attn_axes, attn_init, NEG_INF
from .common import apply_rope
from .dense import mlp_apply, mlp_axes, mlp_init

_C_RGLRU = 8.0


# ------------------------------- RG-LRU core --------------------------------


def rglru_scan(x_gated: jax.Array, log_a: jax.Array, h0: jax.Array | None):
    """h_t = a_t h_{t-1} + b_t with b = sqrt(1-a^2) * x_gated.

    x_gated/log_a: (b, t, w) fp32.  h0: (b, w) or None.  Associative scan."""
    a = jnp.exp(log_a)
    b_term = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * x_gated
    if h0 is not None:
        # Fold the carried state into the first step: b_0 += a_0 * h0.
        b_term = b_term.at[:, 0].add(a[:, 0] * h0)

    def combine(left, right):
        a_l, b_l = left
        a_r, b_r = right
        return a_l * a_r, a_r * b_l + b_r

    _, h = jax.lax.associative_scan(combine, (a, b_term), axis=1)
    return h  # (b, t, w); final state h[:, -1]


def rglru_init(key, cfg) -> dict:
    w = cfg.lru_width
    k1, k2, k3 = jax.random.split(key, 3)
    # Lambda init so a = exp(-c*softplus(L)) lands in [0.9, 0.999] at r=1.
    u = jax.random.uniform(k3, (w,), minval=0.9, maxval=0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / _C_RGLRU))
    return {
        "w_a": dense_init(k1, w, w), "b_a": jnp.zeros((w,), jnp.float32),
        "w_x": dense_init(k2, w, w), "b_x": jnp.zeros((w,), jnp.float32),
        "lam": lam.astype(jnp.float32),
    }


def rglru_apply(params, x, h0, *, bf16_gates: bool = False,
                replicate_weights: bool = False):
    """x: (b, t, w) any dtype -> (out, h_final) in fp32 recurrence.

    bf16_gates: gate MATMULS in bf16 (recurrence itself stays fp32) --
    halves the gate all-reduce payload when the channel dim is sharded
    (§Perf, recurrentgemma train)."""
    xf = x.astype(jnp.float32)
    w_a, w_x = params["w_a"], params["w_x"]
    if replicate_weights:
        # batch-sharded LRU branch: gate weights are small (w^2 ~ 26 MB);
        # replicating them makes the gate matmuls fully local instead of
        # partial-sum all-reduces over the sharded contraction dim
        w_a = wsc(w_a, None, None)
        w_x = wsc(w_x, None, None)
    if bf16_gates:
        xb = x.astype(jnp.bfloat16)
        r_pre = (xb @ w_a.astype(jnp.bfloat16)).astype(jnp.float32)
        i_pre = (xb @ w_x.astype(jnp.bfloat16)).astype(jnp.float32)
    else:
        r_pre = xf @ w_a
        i_pre = xf @ w_x
    r = jax.nn.sigmoid(r_pre + params["b_a"])
    i = jax.nn.sigmoid(i_pre + params["b_x"])
    log_a = -_C_RGLRU * jax.nn.softplus(params["lam"]) * r
    h = rglru_scan(i * xf, log_a, h0)
    return h.astype(x.dtype), h[:, -1]


# --------------------------- recurrent block ---------------------------------


def rec_block_init(key, cfg) -> dict:
    w = cfg.lru_width
    d = cfg.d_model
    ks = jax.random.split(key, 6)
    return {
        "ln1": norm_init(cfg, d),
        "w_in_x": dense_init(ks[0], d, w),
        "w_in_g": dense_init(ks[1], d, w),
        "conv_w": (jax.random.normal(ks[2], (cfg.conv_width, w)) * 0.1).astype(jnp.float32),
        "lru": rglru_init(ks[3], cfg),
        "w_out": dense_init(ks[4], w, d),
        "ln2": norm_init(cfg, d),
        "mlp": mlp_init(ks[5], cfg),
    }


def rec_block_axes(cfg) -> dict:
    return {
        "ln1": {"scale": (None,)},
        "w_in_x": ("embed", "mlp"), "w_in_g": ("embed", "mlp"),
        "conv_w": (None, "mlp"),
        "lru": {"w_a": ("mlp", "mlp2"), "b_a": (None,),
                "w_x": ("mlp", "mlp2"), "b_x": (None,), "lam": ("mlp",)},
        "w_out": ("mlp", "embed"),
        "ln2": {"scale": (None,)},
        "mlp": mlp_axes(cfg),
    }


def rec_block_apply(params, cfg, x, *, mode="train", cache=None):
    b, t, d = x.shape
    ct = x.dtype
    y = norm(x, params["ln1"], cfg)
    xb = y @ params["w_in_x"].astype(ct)
    gb = jax.nn.gelu(y @ params["w_in_g"].astype(ct))

    if getattr(cfg, "lru_batch_shard", False) and mode == "train":
        # reshard batch over every mesh axis: the conv/gates/scan below are
        # channel-local, so full batch sharding removes the gate-matmul
        # partial-sum all-reduces entirely (§Perf, recurrentgemma train)
        full = ("pod", "data", "model")
        xb = wsc(xb, full, None, None)
        gb = wsc(gb, full, None, None)
    if mode == "decode":
        seq = jnp.concatenate([cache["conv"].astype(ct), xb], axis=1)
        xc = conv1d_depthwise_causal(seq, params["conv_w"].astype(ct))[:, -t:]
        new_conv = seq[:, -(cfg.conv_width - 1):]
        h0 = cache["h"]
    else:
        xc = conv1d_depthwise_causal(xb, params["conv_w"].astype(ct))
        new_conv = xb[:, -(cfg.conv_width - 1):]
        h0 = None

    lru_out, h_f = rglru_apply(params["lru"], xc, h0,
                               bf16_gates=getattr(cfg, "lru_bf16_gates", False),
                               replicate_weights=getattr(cfg, "lru_batch_shard", False))
    out = (lru_out * gb) @ params["w_out"].astype(ct)
    x = x + wsc(out, BATCH, None, None)
    x = x + mlp_apply(params["mlp"], cfg, norm(x, params["ln2"], cfg))

    new_cache = None
    if mode in ("prefill", "decode"):
        new_cache = {"h": h_f.astype(jnp.float32),
                     "conv": new_conv.astype(ct)}
    return x, new_cache


def rec_cache_spec(cfg, batch: int) -> dict:
    return {
        "h": jax.ShapeDtypeStruct((batch, cfg.lru_width), jnp.float32),
        "conv": jax.ShapeDtypeStruct(
            (batch, cfg.conv_width - 1, cfg.lru_width),
            dtype_of(cfg.compute_dtype)),
    }


# ------------------- local attention with rotating cache ---------------------


def attn_block_init(key, cfg) -> dict:
    ka, km = jax.random.split(key)
    return {
        "ln1": norm_init(cfg, cfg.d_model),
        "attn": attn_init(ka, cfg),
        "ln2": norm_init(cfg, cfg.d_model),
        "mlp": mlp_init(km, cfg),
    }


def attn_block_axes(cfg) -> dict:
    return {"ln1": {"scale": (None,)}, "attn": attn_axes(cfg),
            "ln2": {"scale": (None,)}, "mlp": mlp_axes(cfg)}


def _rotating_decode_attn(params, cfg, y, cache, rope):
    """Decode against a rotating window cache of capacity W = window."""
    b, t, _ = y.shape
    ct = y.dtype
    W = cfg.attention_window
    pos = cache["len"]  # absolute position of the next token
    q = (y @ params["wq"].astype(ct)).reshape(b, t, cfg.num_heads, cfg.head_dim)
    k = (y @ params["wk"].astype(ct)).reshape(b, t, cfg.num_kv_heads, cfg.head_dim)
    v = (y @ params["wv"].astype(ct)).reshape(b, t, cfg.num_kv_heads, cfg.head_dim)
    cos, sin = rope
    q, k = apply_rope(q, cos, sin), apply_rope(k, cos, sin)
    slot = pos % W
    kc = jax.lax.dynamic_update_slice(cache["k"], k.transpose(0, 2, 1, 3),
                                      (0, 0, slot, 0))
    vc = jax.lax.dynamic_update_slice(cache["v"], v.transpose(0, 2, 1, 3),
                                      (0, 0, slot, 0))
    # Slot j holds absolute position: the largest p <= pos with p % W == j.
    j = jnp.arange(W)
    kpos = pos - ((pos - j) % W)
    valid = (kpos >= 0) & (kpos >= pos - W + 1)
    g = cfg.num_heads // cfg.num_kv_heads
    qh = q.transpose(0, 2, 1, 3).reshape(b, cfg.num_kv_heads, g, t, cfg.head_dim)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qh.astype(jnp.float32),
                   kc.astype(jnp.float32)) * (cfg.head_dim ** -0.5)
    s = jnp.where(valid[None, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", p, vc.astype(jnp.float32))
    out = out.reshape(b, cfg.num_heads, t, cfg.head_dim).transpose(0, 2, 1, 3)
    out = out.reshape(b, t, cfg.q_dim).astype(ct) @ params["wo"].astype(ct)
    return out, {"k": kc, "v": vc, "len": pos + t}


def attn_block_apply(params, cfg, x, *, rope, positions, mode="train", cache=None):
    y = norm(x, params["ln1"], cfg)
    if mode == "decode":
        h, new_cache = _rotating_decode_attn(params["attn"], cfg, y, cache, rope)
        h = wsc(h, BATCH, None, None)
    else:
        h, _ = attn_apply(params["attn"], cfg, y, rope=rope, causal=True,
                          window=cfg.attention_window, mode="train")
        new_cache = None
        if mode == "prefill":
            # Build the rotating cache from the LAST W positions.
            W = cfg.attention_window
            ct = y.dtype
            b, t, _ = y.shape
            k = (y @ params["attn"]["wk"].astype(ct)).reshape(
                b, t, cfg.num_kv_heads, cfg.head_dim)
            v = (y @ params["attn"]["wv"].astype(ct)).reshape(
                b, t, cfg.num_kv_heads, cfg.head_dim)
            cos, sin = rope
            k = apply_rope(k, cos, sin).transpose(0, 2, 1, 3)
            v = v.transpose(0, 2, 1, 3)
            kc = jnp.zeros((b, cfg.num_kv_heads, W, cfg.head_dim), ct)
            vc = jnp.zeros_like(kc)
            # Scatter position p into slot p % W for the last min(t, W) steps.
            take = min(t, W)
            p_abs = jnp.arange(t - take, t)
            slots = p_abs % W
            kc = kc.at[:, :, slots].set(k[:, :, t - take:])
            vc = vc.at[:, :, slots].set(v[:, :, t - take:])
            new_cache = {"k": kc, "v": vc, "len": jnp.asarray(t, jnp.int32)}
    x = x + h
    x = x + mlp_apply(params["mlp"], cfg, norm(x, params["ln2"], cfg))
    return x, new_cache


def attn_cache_spec(cfg, batch: int, dtype=jnp.bfloat16) -> dict:
    W = cfg.attention_window
    shp = (batch, cfg.num_kv_heads, W, cfg.head_dim)
    return {"k": jax.ShapeDtypeStruct(shp, dtype),
            "v": jax.ShapeDtypeStruct(shp, dtype),
            "len": jax.ShapeDtypeStruct((), jnp.int32)}


# --------------------------------- full LM ----------------------------------


def init_lm(key, cfg) -> dict:
    ke, kb, ko = jax.random.split(key, 3)
    blocks = []
    for i, kind in enumerate(cfg.pattern()):
        kk = jax.random.fold_in(kb, i)
        blocks.append(attn_block_init(kk, cfg) if kind == "A"
                      else rec_block_init(kk, cfg))
    p = {"embed": embed_init(ke, cfg.vocab_size, cfg.d_model),
         "blocks": blocks, "ln_f": norm_init(cfg, cfg.d_model)}
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(ko, cfg.d_model, cfg.vocab_size)
    return p


def lm_axes(cfg) -> dict:
    blocks = [attn_block_axes(cfg) if k == "A" else rec_block_axes(cfg)
              for k in cfg.pattern()]
    p = {"embed": ("vocab", "embed"), "blocks": blocks, "ln_f": {"scale": (None,)}}
    if not cfg.tie_embeddings:
        p["lm_head"] = ("embed", "vocab")
    return p


def apply_lm(params, cfg, tokens, *, mode="train", caches=None, positions=None,
             prefix_embeds=None, rope_override=None):
    ct = dtype_of(cfg.compute_dtype)
    x = params["embed"].astype(ct)[tokens] * jnp.asarray(
        cfg.d_model ** 0.5, ct)  # gemma-style embed scaling
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(ct), x], axis=1)
    b, t, _ = x.shape
    x = wsc(x, BATCH, None, None)

    if positions is None:
        offset = 0
        if mode == "decode" and caches is not None:
            for c, kind in zip(caches, cfg.pattern()):
                if kind == "A":
                    offset = c["len"]
                    break
        positions = default_positions(b, t, offset)
    rope = rope_override or rope_angles(positions, cfg.head_dim, cfg.rope_theta)

    if getattr(cfg, "cast_params_pre_scan", False):
        ct2 = dtype_of(cfg.compute_dtype)
        params = dict(params)
        params["blocks"] = jax.tree.map(
            lambda a: a.astype(ct2) if a.dtype == jnp.float32 else a,
            params["blocks"])

    new_caches = []
    for i, kind in enumerate(cfg.pattern()):
        blk = params["blocks"][i]
        cache_l = None if caches is None else caches[i]
        if kind == "A":
            fn = lambda p_, x_, c_: attn_block_apply(
                p_, cfg, x_, rope=rope, positions=positions, mode=mode, cache=c_)
        else:
            fn = lambda p_, x_, c_: rec_block_apply(p_, cfg, x_, mode=mode, cache=c_)
        if cfg.remat != "none" and mode == "train":
            fn = jax.checkpoint(fn)
        x, nc = fn(blk, x, cache_l)
        new_caches.append(nc)

    x = norm(x, params["ln_f"], cfg)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = softcap(x @ head.astype(ct), cfg.logit_softcap)
    return wsc(logits, BATCH, None, "model"), (new_caches if mode != "train" else None)


def init_caches(cfg, batch: int, s_max: int = 0, dtype=jnp.bfloat16) -> list:
    del s_max  # attention caches are bounded by the window; LRU state is O(1)
    return [attn_cache_spec(cfg, batch, dtype) if k == "A"
            else rec_cache_spec(cfg, batch) for k in cfg.pattern()]


def zeros_caches(cfg, batch: int, s_max: int = 0) -> list:
    return [jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), spec)
            for spec in init_caches(cfg, batch)]
