"""Encoder-decoder backbone (Seamless-M4T medium text/speech trunk).

The audio frontend is a STUB per the brief: the encoder consumes
precomputed frame embeddings (b, t_src, d) from ``input_specs()``; the
transformer trunk (what this framework exercises) is complete --
bidirectional encoder, causal decoder with cross-attention, KV caches for
both at serve time.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .attention import attn_apply, attn_axes, attn_cache_spec, attn_init
from .common import (
    BATCH, default_positions, dense_init, dtype_of, embed_init, norm,
    norm_init, rope_angles, wsc,
)
from .dense import mlp_apply, mlp_axes, mlp_init, _maybe_remat


# ------------------------------ encoder -------------------------------------


def enc_block_init(key, cfg):
    ka, km = jax.random.split(key)
    return {"ln1": norm_init(cfg, cfg.d_model), "attn": attn_init(ka, cfg),
            "ln2": norm_init(cfg, cfg.d_model), "mlp": mlp_init(km, cfg)}


def _norm_axes(cfg):
    return ({"scale": (None,), "bias": (None,)} if cfg.norm_type == "layernorm"
            else {"scale": (None,)})


def enc_block_axes(cfg):
    na = _norm_axes(cfg)
    return {"ln1": dict(na), "attn": attn_axes(cfg), "ln2": dict(na),
            "mlp": mlp_axes(cfg)}


def encode(params, cfg, frames):
    """frames: (b, t_src, d_model) stub embeddings -> encoder output."""
    ct = dtype_of(cfg.compute_dtype)
    x = wsc(frames.astype(ct), BATCH, None, None)
    b, t, _ = x.shape
    rope = rope_angles(default_positions(b, t), cfg.head_dim, cfg.rope_theta)

    def body(carry, blk):
        y = carry
        h, _ = attn_apply(blk["attn"], cfg, norm(y, blk["ln1"], cfg),
                          rope=rope, causal=False, mode="train")
        y = y + h
        y = y + mlp_apply(blk["mlp"], cfg, norm(y, blk["ln2"], cfg))
        return y, None

    x, _ = jax.lax.scan(_maybe_remat(body, cfg), x, params["enc_blocks"])
    return norm(x, params["enc_ln"], cfg)


# ------------------------------ decoder -------------------------------------


def dec_block_init(key, cfg):
    ka, kc, km = jax.random.split(key, 3)
    return {
        "ln1": norm_init(cfg, cfg.d_model), "self_attn": attn_init(ka, cfg),
        "ln2": norm_init(cfg, cfg.d_model), "cross_attn": attn_init(kc, cfg, cross=True),
        "ln3": norm_init(cfg, cfg.d_model), "mlp": mlp_init(km, cfg),
    }


def dec_block_axes(cfg):
    na = _norm_axes(cfg)
    return {"ln1": dict(na), "self_attn": attn_axes(cfg),
            "ln2": dict(na), "cross_attn": attn_axes(cfg, cross=True),
            "ln3": dict(na), "mlp": mlp_axes(cfg)}


def dec_block_apply(params, cfg, x, enc_out, *, rope, mode, cache=None):
    """cache: {"self": kv-cache, "cross": kv-cache} or None."""
    c_self = None if cache is None else cache["self"]
    c_cross = None if cache is None else cache["cross"]
    h, nc_self = attn_apply(params["self_attn"], cfg, norm(x, params["ln1"], cfg),
                            rope=rope, causal=True, mode=mode, cache=c_self)
    x = x + h
    h, nc_cross = attn_apply(params["cross_attn"], cfg, norm(x, params["ln2"], cfg),
                             rope=None, kv_x=enc_out, mode=mode, cache=c_cross)
    x = x + h
    x = x + mlp_apply(params["mlp"], cfg, norm(x, params["ln3"], cfg))
    nc = None
    if nc_self is not None:
        nc = {"self": nc_self, "cross": nc_cross}
    return x, nc


# ------------------------------ full model ----------------------------------


def init_lm(key, cfg) -> dict:
    ke, kb1, kb2, ko = jax.random.split(key, 4)
    enc_keys = jax.random.split(kb1, cfg.num_encoder_layers)
    dec_keys = jax.random.split(kb2, cfg.num_layers)
    return {
        "embed": embed_init(ke, cfg.vocab_size, cfg.d_model),
        "enc_blocks": jax.vmap(lambda k: enc_block_init(k, cfg))(enc_keys),
        "enc_ln": norm_init(cfg, cfg.d_model),
        "dec_blocks": jax.vmap(lambda k: dec_block_init(k, cfg))(dec_keys),
        "ln_f": norm_init(cfg, cfg.d_model),
        "lm_head": dense_init(ko, cfg.d_model, cfg.vocab_size),
    }


def lm_axes(cfg) -> dict:
    na = _norm_axes(cfg)
    lift = lambda tree: jax.tree.map(lambda ax: ("layers",) + ax, tree,
                                     is_leaf=lambda x: isinstance(x, tuple))
    return {
        "embed": ("vocab", "embed"),
        "enc_blocks": lift(enc_block_axes(cfg)),
        "enc_ln": dict(na),
        "dec_blocks": lift(dec_block_axes(cfg)),
        "ln_f": dict(na),
        "lm_head": ("embed", "vocab"),
    }


def apply_lm(params, cfg, tokens, *, frames=None, enc_out=None, mode="train",
             caches=None, positions=None, prefix_embeds=None, rope_override=None):
    """Teacher-forced seq2seq (train) or cached decode.

    train/prefill: ``frames`` (b, t_src, d) required; decode: pass
    ``caches`` (the cross cache pins the encoder output)."""
    del rope_override
    if prefix_embeds is not None and frames is None:
        frames = prefix_embeds  # launch-layer uniform calling convention
    ct = dtype_of(cfg.compute_dtype)
    if mode != "decode":
        enc_out = encode(params, cfg, frames)

    x = params["embed"].astype(ct)[tokens]
    b, t, _ = x.shape
    x = wsc(x, BATCH, None, None)
    if positions is None:
        offset = caches["self"]["len"][0] if mode == "decode" else 0
        positions = default_positions(b, t, offset)
    rope = rope_angles(positions, cfg.head_dim, cfg.rope_theta)

    if mode == "decode":
        enc_out = jnp.zeros((b, 0, cfg.d_model), ct)  # unused; cross uses cache

    def body(carry, xs):
        blk, cache_l = xs
        y, nc = dec_block_apply(blk, cfg, carry, enc_out, rope=rope,
                                mode=mode, cache=cache_l)
        return y, nc

    x, new_caches = jax.lax.scan(_maybe_remat(body, cfg), x,
                                 (params["dec_blocks"], caches))
    x = norm(x, params["ln_f"], cfg)
    logits = x @ params["lm_head"].astype(ct)
    return wsc(logits, BATCH, None, "model"), (new_caches if mode != "train" else None)


def init_caches(cfg, batch: int, s_max: int, t_src: int | None = None,
                dtype=jnp.bfloat16) -> dict:
    t_src = t_src or s_max
    one = {"self": attn_cache_spec(cfg, batch, s_max, dtype),
           "cross": attn_cache_spec(cfg, batch, t_src, dtype)}
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((cfg.num_layers, *s.shape), s.dtype), one)


def zeros_caches(cfg, batch: int, s_max: int, t_src: int | None = None) -> dict:
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        init_caches(cfg, batch, s_max, t_src))
